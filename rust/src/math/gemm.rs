//! GEMM / GEMV — the paper's two "significant kernels" (Table 3).
//!
//! `gemm` computes `C = alpha * op(A) * op(B) + beta * C` for row-major
//! matrices, like `caffe_cpu_gemm`. Shapes above a small-work threshold
//! take the *packed* path for every transpose combination: the operands
//! are repacked into contiguous micro-panels (MR=4 rows of op(A), NR=16
//! columns of op(B), alpha folded into the A pack) and a 4×16
//! register-accumulator micro-kernel runs over the full depth, sharded
//! across the intra-op thread pool (`util::pool`) along N — or along M
//! when the output is tall and narrow. Packing pays off three ways: the
//! micro-kernel reads both operands contiguously regardless of transpose,
//! the 4×16 accumulator block auto-vectorizes to FMA lanes, and threads
//! share nothing but read-only inputs.
//!
//! Determinism: each C element is produced by exactly one task and its
//! k-loop always runs 0..k in order (no depth blocking of the
//! accumulator), so results are bit-identical at any thread count — and,
//! for `beta == 0`, bit-identical to the unpacked small paths too: every
//! path folds alpha per term and evaluates `fl(fl(alpha*a)*b)` in the
//! same order into a zero accumulator. That's what keeps serve's
//! batched==single bit-exactness guarantee intact with threads on, even
//! when a layer's batch-1 shape dispatches small while its batched shape
//! dispatches packed.
//!
//! The zero-skip fast path (`if a == 0.0 { continue }`) survives ONLY in
//! the unpacked small paths (NN remainder rows, the generic row-axpy
//! form, gemv's transposed form) — never in the packed path, where it
//! would distort benchmarks on zero-filled buffers and add a branch per
//! FMA for no steady-state win. See `zero_rows_still_apply_beta` for the
//! pinned semantics.

use crate::util::pool;
use std::cell::RefCell;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trans {
    No,
    Yes,
}

/// Micro-panel height of op(A).
const MR: usize = 4;
/// Micro-panel width of op(B).
const NR: usize = 16;
/// Rows of op(A) packed per block (bounds the per-thread A scratch).
const MC: usize = 64;
/// Columns of op(B) packed per stripe block (bounds the B scratch).
const NC: usize = 256;
/// Below this many multiply-adds (m*n*k) packing costs more than it saves.
const PACK_MIN_MULS: usize = 32 * 32 * 32;

/// Effective (MC, NC) block sizes for depth `k`. Panels pack the *full*
/// depth (the accumulator is never split, which is what makes results
/// bit-identical across thread counts and dispatch paths), so at very
/// large k the row/column block counts shrink instead — capping the
/// per-thread pack scratch at ~¼ MiB of A and ~1 MiB of B even for
/// VGG-FC-sized depths, at the cost of more frequent re-packing there.
/// Depends only on shape, never on the thread budget.
fn block_sizes(k: usize) -> (usize, usize) {
    const A_BUDGET: usize = 64 * 1024; // elements: 256 KiB of f32
    const B_BUDGET: usize = 256 * 1024; // elements: 1 MiB of f32
    let mc = (A_BUDGET / k.max(1) / MR * MR).clamp(MR, MC);
    let nc = (B_BUDGET / k.max(1) / NR * NR).clamp(NR, NC);
    (mc, nc)
}

/// Row-major GEMM: C[m,n] = alpha*op(A)[m,k]*op(B)[k,n] + beta*C.
///
/// `a` is m×k when `ta == No`, k×m when `ta == Yes` (same storage order as
/// caffe_cpu_gemm's lda conventions).
pub fn gemm(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    assert!(c.len() >= m * n, "gemm: C too small");
    assert!(
        a.len() >= m * k && b.len() >= k * n,
        "gemm {ta:?}{tb:?}: input too small"
    );
    if m == 0 || n == 0 {
        return;
    }
    // Dispatch on shape only (never on thread count), so a given shape
    // always takes the same code path and stays deterministic.
    if m * n * k >= PACK_MIN_MULS {
        gemm_packed(ta, tb, m, n, k, alpha, a, b, beta, c);
    } else if (ta, tb) == (Trans::No, Trans::No) {
        gemm_nn_small(m, n, k, alpha, a, b, beta, c);
    } else {
        gemm_generic(ta, tb, m, n, k, alpha, a, b, beta, c);
    }
}

// ---------------------------------------------------------------------------
// Packed path
// ---------------------------------------------------------------------------

thread_local! {
    /// Per-thread packing scratch (A-panel, B-panel). Reused across calls
    /// so the steady state allocates nothing — the math-layer analogue of
    /// the device `ScratchPool`.
    static PACK_A: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    static PACK_B: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// op(A)[r, kk] for the given storage layout.
#[inline(always)]
fn a_at(ta: Trans, a: &[f32], m: usize, k: usize, r: usize, kk: usize) -> f32 {
    match ta {
        Trans::No => a[r * k + kk],
        Trans::Yes => a[kk * m + r],
    }
}

/// Pack `alpha * op(A)[rows, 0..k]` into MR-row micro-panels:
/// `buf[(panel, kk, i)] = alpha * op(A)[rows.start + panel*MR + i, kk]`,
/// zero-padded to a multiple of MR rows.
fn pack_a(
    ta: Trans,
    a: &[f32],
    m: usize,
    k: usize,
    rows: std::ops::Range<usize>,
    alpha: f32,
    buf: &mut Vec<f32>,
) {
    let panels = rows.len().div_ceil(MR);
    buf.resize(panels * MR * k, 0.0);
    for p in 0..panels {
        let base = p * MR * k;
        let r0 = rows.start + p * MR;
        let live = MR.min(rows.end - r0);
        for kk in 0..k {
            let dst = &mut buf[base + kk * MR..base + kk * MR + MR];
            for (i, d) in dst.iter_mut().enumerate() {
                *d = if i < live {
                    alpha * a_at(ta, a, m, k, r0 + i, kk)
                } else {
                    0.0
                };
            }
        }
    }
}

/// Pack `op(B)[0..k, cols]` into NR-column micro-panels:
/// `buf[(panel, kk, j)] = op(B)[kk, cols.start + panel*NR + j]`,
/// zero-padded to a multiple of NR columns.
fn pack_b(
    tb: Trans,
    b: &[f32],
    k: usize,
    n: usize,
    cols: std::ops::Range<usize>,
    buf: &mut Vec<f32>,
) {
    let panels = cols.len().div_ceil(NR);
    buf.resize(panels * NR * k, 0.0);
    for p in 0..panels {
        let base = p * NR * k;
        let j0 = cols.start + p * NR;
        let live = NR.min(cols.end - j0);
        match tb {
            Trans::No => {
                for kk in 0..k {
                    let src = &b[kk * n + j0..kk * n + j0 + live];
                    let dst = &mut buf[base + kk * NR..base + kk * NR + NR];
                    dst[..live].copy_from_slice(src);
                    for d in dst[live..].iter_mut() {
                        *d = 0.0;
                    }
                }
            }
            Trans::Yes => {
                for kk in 0..k {
                    let dst = &mut buf[base + kk * NR..base + kk * NR + NR];
                    for (j, d) in dst.iter_mut().enumerate() {
                        *d = if j < live { b[(j0 + j) * k + kk] } else { 0.0 };
                    }
                }
            }
        }
    }
}

/// The 4×16 micro-kernel: acc[i][j] += ap[kk,i] * bp[kk,j] over the full
/// depth. Both panels are contiguous, so the j-loop vectorizes and the
/// accumulators stay in registers.
#[inline]
fn micro_kernel(k: usize, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    debug_assert!(ap.len() >= k * MR && bp.len() >= k * NR);
    for kk in 0..k {
        // Fixed-size views: tells LLVM the lane widths are compile-time
        // constants so the j-loop stays a straight run of FMA lanes.
        let av: &[f32; MR] = ap[kk * MR..kk * MR + MR].try_into().unwrap();
        let bv: &[f32; NR] = bp[kk * NR..kk * NR + NR].try_into().unwrap();
        for i in 0..MR {
            let ai = av[i];
            let row = &mut acc[i];
            for j in 0..NR {
                row[j] += ai * bv[j];
            }
        }
    }
}

/// Compute `C[rows, cols] = op(A)[rows, :] * op(B)[:, cols] + beta*C`
/// (alpha folded into the A pack). The accumulator runs the full depth,
/// so each C element is written exactly once — beta folds into that
/// single writeback, and `beta == 0` *overwrites* (stale NaN/Inf never
/// leaks through `0*C`).
///
/// # Safety contract
/// `c` windows derived from `rows × cols` must be disjoint across
/// concurrently running calls — guaranteed by the caller sharding
/// disjoint row or column ranges.
#[allow(clippy::too_many_arguments)]
fn packed_region(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &pool::SendPtr<f32>,
    rows: std::ops::Range<usize>,
    cols: std::ops::Range<usize>,
) {
    let (mc_max, nc_max) = block_sizes(k);
    PACK_A.with(|pa| {
        PACK_B.with(|pb| {
            let mut abuf = pa.borrow_mut();
            let mut bbuf = pb.borrow_mut();
            let mut jc = cols.start;
            while jc < cols.end {
                let nc = nc_max.min(cols.end - jc);
                pack_b(tb, b, k, n, jc..jc + nc, &mut bbuf);
                let npanels = nc.div_ceil(NR);
                let mut ic = rows.start;
                while ic < rows.end {
                    let mc = mc_max.min(rows.end - ic);
                    pack_a(ta, a, m, k, ic..ic + mc, alpha, &mut abuf);
                    let mpanels = mc.div_ceil(MR);
                    for mp in 0..mpanels {
                        let ap = &abuf[mp * MR * k..(mp + 1) * MR * k];
                        let r0 = ic + mp * MR;
                        let rmax = MR.min(ic + mc - r0);
                        for np in 0..npanels {
                            let bp = &bbuf[np * NR * k..(np + 1) * NR * k];
                            let j0 = jc + np * NR;
                            let jmax = NR.min(jc + nc - j0);
                            let mut acc = [[0f32; NR]; MR];
                            micro_kernel(k, ap, bp, &mut acc);
                            for i in 0..rmax {
                                // Safety: rows/cols ranges are disjoint
                                // across tasks and inside bounds (r0+i < m,
                                // j0 + jmax <= n).
                                let crow =
                                    unsafe { c.slice((r0 + i) * n + j0, jmax) };
                                let av = &acc[i];
                                if beta == 0.0 {
                                    crow.copy_from_slice(&av[..jmax]);
                                } else if beta == 1.0 {
                                    for (cv, av) in crow.iter_mut().zip(av.iter()) {
                                        *cv += *av;
                                    }
                                } else {
                                    for (cv, av) in crow.iter_mut().zip(av.iter()) {
                                        *cv = *av + beta * *cv;
                                    }
                                }
                            }
                        }
                    }
                    ic += mc;
                }
                jc += nc;
            }
        })
    });
}

fn gemm_packed(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    // No beta pre-pass: every C element is written exactly once by its
    // micro-tile (the accumulator is never depth-split), so beta folds
    // into that writeback — one sweep over C instead of two.
    let cptr = pool::SendPtr::new(c.as_mut_ptr());
    // Shard whichever dimension offers more micro-panels of parallelism
    // (shape-only decision, so the path never depends on thread count).
    // Tasks get contiguous *panel* ranges, so interior chunk boundaries
    // stay NR/MR-aligned and only the final panel is zero-padded.
    let npanels = n.div_ceil(NR);
    let mpanels = m.div_ceil(MR);
    if npanels >= mpanels {
        // N-sharded: each task packs its own column stripe of B exactly
        // once; the (smaller) A re-pack is duplicated per task.
        pool::parallel_for(0..npanels, 1, |pr| {
            let cols = pr.start * NR..(pr.end * NR).min(n);
            packed_region(ta, tb, m, n, k, alpha, a, b, beta, &cptr, 0..m, cols);
        });
    } else {
        // Tall-and-narrow C (e.g. conv data-grad TN with a small output
        // map): shard M; the duplicated B pack is only k*n floats and n
        // is small on this branch.
        pool::parallel_for(0..mpanels, 1, |pr| {
            let rows = pr.start * MR..(pr.end * MR).min(m);
            packed_region(ta, tb, m, n, k, alpha, a, b, beta, &cptr, rows, 0..n);
        });
    }
}

// ---------------------------------------------------------------------------
// Small unpacked paths
// ---------------------------------------------------------------------------

/// Serial beta prologue shared by the small paths. The invariant lives
/// here once: `beta == 0` must *overwrite* — stale NaN/Inf in C must
/// not leak through `0*C`.
fn apply_beta(c: &mut [f32], beta: f32) {
    if beta == 0.0 {
        for v in c.iter_mut() {
            *v = 0.0;
        }
    } else if beta != 1.0 {
        for v in c.iter_mut() {
            *v *= beta;
        }
    }
}

/// Unpacked NN kernel for shapes too small to amortize packing. The
/// 4-row micro loop accumulates over contiguous B rows; only the
/// single-row *remainder* loop keeps the zero-skip fast path.
fn gemm_nn_small(
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    apply_beta(&mut c[..m * n], beta);
    let mut i = 0;
    while i + 4 <= m {
        let (r0, r1, r2, r3) = (i, i + 1, i + 2, i + 3);
        for kk in 0..k {
            let a0 = alpha * a[r0 * k + kk];
            let a1 = alpha * a[r1 * k + kk];
            let a2 = alpha * a[r2 * k + kk];
            let a3 = alpha * a[r3 * k + kk];
            let brow = &b[kk * n..kk * n + n];
            let c0 = r0 * n;
            let c1 = r1 * n;
            let c2 = r2 * n;
            let c3 = r3 * n;
            for (jj, &bv) in brow.iter().enumerate() {
                c[c0 + jj] += a0 * bv;
                c[c1 + jj] += a1 * bv;
                c[c2 + jj] += a2 * bv;
                c[c3 + jj] += a3 * bv;
            }
        }
        i += 4;
    }
    // Remainder rows: the one place the zero-skip survives.
    while i < m {
        for kk in 0..k {
            let av = alpha * a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..kk * n + n];
            let crow = &mut c[i * n..i * n + n];
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += av * bv;
            }
        }
        i += 1;
    }
}

fn gemm_generic(
    ta: Trans,
    tb: Trans,
    m: usize,
    n: usize,
    k: usize,
    alpha: f32,
    a: &[f32],
    b: &[f32],
    beta: f32,
    c: &mut [f32],
) {
    for i in 0..m {
        match tb {
            Trans::No => {
                // Accumulate row-wise over contiguous B rows.
                let crow = &mut c[i * n..(i + 1) * n];
                apply_beta(crow, beta);
                for kk in 0..k {
                    let av = alpha * a_at(ta, a, m, k, i, kk);
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..kk * n + n];
                    for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                        *cv += av * bv;
                    }
                }
            }
            Trans::Yes => {
                for j in 0..n {
                    let mut acc = 0.0f32;
                    // B^T: element (kk, j) is b[j * k + kk] — contiguous in kk.
                    // Alpha folds per term, like the packed path and the NN
                    // small path, so a layer whose batch-1 shape lands here
                    // while its batched shape goes packed still produces
                    // bit-identical per-sample results for any alpha.
                    let bcol = &b[j * k..j * k + k];
                    for (kk, &bv) in bcol.iter().enumerate() {
                        acc += (alpha * a_at(ta, a, m, k, i, kk)) * bv;
                    }
                    let idx = i * n + j;
                    // beta == 0 overwrites — stale NaN/Inf in C must not
                    // leak through 0*C (matches the packed path).
                    c[idx] = if beta == 0.0 {
                        acc
                    } else {
                        acc + beta * c[idx]
                    };
                }
            }
        }
    }
}

/// Row-major GEMV: y = alpha*op(A)*x + beta*y, A is m×n. The untransposed
/// row-dot form shards rows across the pool (disjoint y elements, k-order
/// fixed ⇒ deterministic); the transposed form is an axpy accumulation
/// into all of y and stays serial to keep summation order fixed.
pub fn gemv(
    ta: Trans,
    m: usize,
    n: usize,
    alpha: f32,
    a: &[f32],
    x: &[f32],
    beta: f32,
    y: &mut [f32],
) {
    match ta {
        Trans::No => {
            assert!(a.len() >= m * n && x.len() >= n && y.len() >= m);
            let grain = (pool::GRAIN_ELEMWISE / n.max(1)).max(1);
            pool::parallel_chunks_mut(&mut y[..m], grain, |off, ych| {
                for (d, yv) in ych.iter_mut().enumerate() {
                    let i = off + d;
                    let row = &a[i * n..i * n + n];
                    let mut acc = 0.0f32;
                    for (av, xv) in row.iter().zip(x.iter()) {
                        acc += av * xv;
                    }
                    // beta == 0 overwrites (stale NaN/Inf must not leak).
                    *yv = if beta == 0.0 {
                        alpha * acc
                    } else {
                        alpha * acc + beta * *yv
                    };
                }
            });
        }
        Trans::Yes => {
            assert!(a.len() >= m * n && x.len() >= m && y.len() >= n);
            apply_beta(&mut y[..n], beta);
            for i in 0..m {
                let av = alpha * x[i];
                if av == 0.0 {
                    continue;
                }
                let row = &a[i * n..i * n + n];
                for (yv, rv) in y[..n].iter_mut().zip(row.iter()) {
                    *yv += av * rv;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::tcheck;

    pub(crate) fn naive_gemm(
        ta: Trans,
        tb: Trans,
        m: usize,
        n: usize,
        k: usize,
        alpha: f32,
        a: &[f32],
        b: &[f32],
        beta: f32,
        c: &mut [f32],
    ) {
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for kk in 0..k {
                    let av = match ta {
                        Trans::No => a[i * k + kk],
                        Trans::Yes => a[kk * m + i],
                    };
                    let bv = match tb {
                        Trans::No => b[kk * n + j],
                        Trans::Yes => b[j * k + kk],
                    };
                    acc += av * bv;
                }
                c[i * n + j] = alpha * acc + beta * c[i * n + j];
            }
        }
    }

    #[test]
    fn small_closed_form() {
        // [[1,2],[3,4]] * [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut c = [0.0; 4];
        gemm(Trans::No, Trans::No, 2, 2, 2, 1.0, &a, &b, 0.0, &mut c);
        assert_eq!(c, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn alpha_beta() {
        let a = [1.0, 0.0, 0.0, 1.0];
        let b = [2.0, 0.0, 0.0, 2.0];
        let mut c = [1.0, 1.0, 1.0, 1.0];
        gemm(Trans::No, Trans::No, 2, 2, 2, 0.5, &a, &b, 2.0, &mut c);
        assert_eq!(c, [3.0, 2.0, 2.0, 3.0]);
    }

    #[test]
    fn all_transpose_combos_match_naive() {
        tcheck::check("gemm_vs_naive", 48, |rng| {
            let m = rng.range_u(1, 33) as usize;
            let n = rng.range_u(1, 33) as usize;
            let k = rng.range_u(1, 33) as usize;
            let ta = if rng.bernoulli(0.5) { Trans::Yes } else { Trans::No };
            let tb = if rng.bernoulli(0.5) { Trans::Yes } else { Trans::No };
            let alpha = rng.uniform(-2.0, 2.0);
            let beta = if rng.bernoulli(0.5) { 0.0 } else { rng.uniform(-1.0, 1.0) };
            let mut a = vec![0.0; m * k];
            let mut b = vec![0.0; k * n];
            let mut c = vec![0.0; m * n];
            rng.fill_uniform(&mut a, -1.0, 1.0);
            rng.fill_uniform(&mut b, -1.0, 1.0);
            rng.fill_uniform(&mut c, -1.0, 1.0);
            let mut c_ref = c.clone();
            gemm(ta, tb, m, n, k, alpha, &a, &b, beta, &mut c);
            naive_gemm(ta, tb, m, n, k, alpha, &a, &b, beta, &mut c_ref);
            tcheck::close(&c, &c_ref, 1e-4, 1e-4)
        });
    }

    /// Packed path at shapes crossing every tile boundary (MR, NR, MC,
    /// NC), at thread budgets 1 / 2 / max, for all transpose combos.
    #[test]
    fn packed_matches_naive_across_tile_boundaries_and_threads() {
        // (m, n, k) straddling MR=4, NR=16, MC=64, NC=256 edges; every
        // shape clears the packed-path threshold.
        let shapes = [
            (4, 16, 2048),   // exact micro tile
            (5, 17, 513),    // one past micro tile, k past nothing special
            (3, 260, 64),    // m below MR, n past NC
            (63, 255, 33),   // one below MC / NC
            (65, 257, 40),   // one past MC / NC
            (128, 31, 70),   // tall-and-narrow: M-sharded branch
            (260, 15, 48),   // n < NR with m past NC
        ];
        let max_t = crate::util::pool::default_threads();
        for &(m, n, k) in &shapes {
            assert!(m * n * k >= PACK_MIN_MULS, "shape must take packed path");
            for ta in [Trans::No, Trans::Yes] {
                for tb in [Trans::No, Trans::Yes] {
                    let mut rng = Pcg32::new((m * 31 + n * 7 + k) as u64);
                    let mut a = vec![0.0; m * k];
                    let mut b = vec![0.0; k * n];
                    let mut c0 = vec![0.0; m * n];
                    rng.fill_uniform(&mut a, -1.0, 1.0);
                    rng.fill_uniform(&mut b, -1.0, 1.0);
                    rng.fill_uniform(&mut c0, -1.0, 1.0);
                    let mut c_ref = c0.clone();
                    naive_gemm(ta, tb, m, n, k, 1.3, &a, &b, 0.7, &mut c_ref);
                    for t in [1usize, 2, max_t] {
                        let mut c = c0.clone();
                        crate::util::pool::with_intra_op(t, || {
                            gemm(ta, tb, m, n, k, 1.3, &a, &b, 0.7, &mut c);
                        });
                        tcheck::close(&c, &c_ref, 1e-3, 1e-4).unwrap_or_else(|e| {
                            panic!("{ta:?}{tb:?} m={m} n={n} k={k} t={t}: {e}")
                        });
                    }
                }
            }
        }
    }

    /// A layer whose batch-1 shape dispatches to the small path while its
    /// batched shape dispatches packed must still give bit-identical
    /// per-sample rows at beta == 0 (serve's batched==single guarantee) —
    /// for any alpha, since every path folds alpha per term.
    #[test]
    fn small_and_packed_paths_agree_bitwise_at_beta_zero() {
        let (n, k) = (10usize, 500usize); // LeNet ip2-like NT shape
        let mut rng = Pcg32::new(17);
        let mut w = vec![0.0; n * k]; // B^T storage (n×k)
        rng.fill_uniform(&mut w, -1.0, 1.0);
        let mut x1 = vec![0.0; k]; // one sample
        rng.fill_uniform(&mut x1, -1.0, 1.0);
        let m = 8;
        assert!(n * k < PACK_MIN_MULS, "batch-1 must take the small path");
        assert!(m * n * k >= PACK_MIN_MULS, "batch-8 must take the packed path");
        for alpha in [1.0f32, 0.5] {
            let mut c1 = vec![0.0f32; n];
            gemm(Trans::No, Trans::Yes, 1, n, k, alpha, &x1, &w, 0.0, &mut c1);
            let mut xs = vec![0.0f32; m * k];
            xs[..k].copy_from_slice(&x1);
            rng.fill_uniform(&mut xs[k..], -1.0, 1.0);
            let mut c8 = vec![0.0f32; m * n];
            gemm(Trans::No, Trans::Yes, m, n, k, alpha, &xs, &w, 0.0, &mut c8);
            assert_eq!(c1[..], c8[..n], "alpha={alpha}: batched row 0 differs");
        }
    }

    /// Thread count must not change a single bit of the result.
    #[test]
    fn packed_is_bit_identical_across_thread_counts() {
        let (m, n, k) = (37, 300, 129);
        let mut rng = Pcg32::new(9);
        let mut a = vec![0.0; m * k];
        let mut b = vec![0.0; k * n];
        rng.fill_uniform(&mut a, -1.0, 1.0);
        rng.fill_uniform(&mut b, -1.0, 1.0);
        let run = |t: usize| {
            let mut c = vec![0.0f32; m * n];
            crate::util::pool::with_intra_op(t, || {
                gemm(Trans::No, Trans::No, m, n, k, 1.0, &a, &b, 0.0, &mut c);
            });
            c
        };
        let c1 = run(1);
        for t in [2, 3, crate::util::pool::default_threads()] {
            assert_eq!(c1, run(t), "thread count {t} changed bits");
        }
    }

    /// Zero rows in A must still see beta applied to C — the zero-skip
    /// fast path may only skip the *accumulation*, never the beta scale.
    /// Pinned for both the packed path and the unpacked remainder path.
    #[test]
    fn zero_rows_still_apply_beta() {
        for (m, n, k) in [(3usize, 5usize, 4usize), (33, 64, 64)] {
            let mut rng = Pcg32::new(11);
            let mut a = vec![0.0; m * k];
            let mut b = vec![0.0; k * n];
            let mut c = vec![0.0; m * n];
            rng.fill_uniform(&mut a, -1.0, 1.0);
            rng.fill_uniform(&mut b, -1.0, 1.0);
            rng.fill_uniform(&mut c, -1.0, 1.0);
            // Last row of A (the remainder row when m % 4 != 0) all zero,
            // plus scattered exact zeros elsewhere.
            for v in a[(m - 1) * k..m * k].iter_mut() {
                *v = 0.0;
            }
            a[0] = 0.0;
            let mut c_ref = c.clone();
            gemm(Trans::No, Trans::No, m, n, k, 1.0, &a, &b, 2.5, &mut c);
            naive_gemm(Trans::No, Trans::No, m, n, k, 1.0, &a, &b, 2.5, &mut c_ref);
            tcheck::close(&c, &c_ref, 1e-4, 1e-4).unwrap();
            // The zero row's output must be exactly beta * c_before.
            for j in 0..n {
                assert_eq!(c[(m - 1) * n + j], c_ref[(m - 1) * n + j]);
            }
        }
    }

    #[test]
    fn large_shapes_cross_tile_boundaries() {
        let mut rng = Pcg32::new(5);
        // m not divisible by 4/MC; k large; n crosses NC.
        let (m, n, k) = (67, 521, 300);
        let mut a = vec![0.0; m * k];
        let mut b = vec![0.0; k * n];
        rng.fill_uniform(&mut a, -1.0, 1.0);
        rng.fill_uniform(&mut b, -1.0, 1.0);
        let mut c = vec![0.0; m * n];
        let mut c_ref = vec![0.0; m * n];
        gemm(Trans::No, Trans::No, m, n, k, 1.0, &a, &b, 0.0, &mut c);
        naive_gemm(Trans::No, Trans::No, m, n, k, 1.0, &a, &b, 0.0, &mut c_ref);
        tcheck::close(&c, &c_ref, 1e-3, 1e-4).unwrap();
    }

    #[test]
    fn gemv_matches_gemm() {
        tcheck::check("gemv_vs_gemm", 32, |rng| {
            let m = rng.range_u(1, 40) as usize;
            let n = rng.range_u(1, 40) as usize;
            let t = if rng.bernoulli(0.5) { Trans::Yes } else { Trans::No };
            let (xl, yl) = match t {
                Trans::No => (n, m),
                Trans::Yes => (m, n),
            };
            let mut a = vec![0.0; m * n];
            let mut x = vec![0.0; xl];
            let mut y = vec![0.0; yl];
            rng.fill_uniform(&mut a, -1.0, 1.0);
            rng.fill_uniform(&mut x, -1.0, 1.0);
            rng.fill_uniform(&mut y, -1.0, 1.0);
            let mut y_ref = y.clone();
            gemv(t, m, n, 1.5, &a, &x, 0.5, &mut y);
            // gemv == gemm with a 1-column vector, using matching op dims.
            match t {
                Trans::No => naive_gemm(Trans::No, Trans::No, m, 1, n, 1.5, &a, &x, 0.5, &mut y_ref),
                Trans::Yes => naive_gemm(Trans::Yes, Trans::No, n, 1, m, 1.5, &a, &x, 0.5, &mut y_ref),
            }
            tcheck::close(&y, &y_ref, 1e-4, 1e-4)
        });
    }
}
