//! LRN layer (cross-channel), decomposed into the paper's three kernels:
//! `LRN_Scale` + `LRN_Output` forward, `LRN_Diff` backward — which is why
//! GoogLeNet's 2 LRN layers produce 2 instances of each in Table 2.

use super::{Layer, SharedBlob};
use crate::blob::Blob;
use crate::device::{Device, Kernel, KernelCall};
use crate::proto::{LayerParameter, LrnParameter};

pub struct LrnLayer {
    name: String,
    p: LrnParameter,
    scale: Option<SharedBlob>,
    dims: (usize, usize, usize), // (num, channels, spatial dim)
}

impl LrnLayer {
    pub fn new(param: &LayerParameter) -> LrnLayer {
        LrnLayer {
            name: param.name.clone(),
            p: param.lrn.clone().unwrap_or_default(),
            scale: None,
            dims: (0, 0, 0),
        }
    }
}

impl Layer for LrnLayer {
    fn name(&self) -> &str {
        &self.name
    }
    fn kind(&self) -> &'static str {
        "LRN"
    }

    fn setup(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        self.scale = Some(super::shared(Blob::new("scale", &[1])));
        self.reshape(dev, bottoms, tops)
    }

    fn reshape(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        let b = bottoms[0].borrow();
        let shape = b.shape().to_vec();
        self.dims = (b.num(), b.channels(), b.height() * b.width());
        drop(b);
        tops[0].borrow_mut().reshape_grow_only(dev, &shape);
        self.scale
            .as_ref()
            .expect("scale blob created at setup")
            .borrow_mut()
            .reshape_grow_only(dev, &shape);
        Ok(())
    }

    fn forward(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<f32> {
        let (num, channels, dim) = self.dims;
        let b_id = bottoms[0].borrow_mut().data.dev_data(dev);
        let s_id = self.scale.as_ref().unwrap().borrow_mut().data.dev_data_mut(dev);
        dev.launch(&KernelCall::new(
            Kernel::LrnScale {
                num,
                channels,
                dim,
                local_size: self.p.local_size,
                alpha: self.p.alpha,
                k: self.p.k,
            },
            &[b_id],
            &[s_id],
        ))?;
        let t_id = tops[0].borrow_mut().data.dev_data_mut(dev);
        dev.launch(&KernelCall::new(
            Kernel::LrnOutput { n: num * channels * dim, beta: self.p.beta },
            &[b_id, s_id],
            &[t_id],
        ))?;
        Ok(0.0)
    }

    fn backward(
        &mut self,
        dev: &mut dyn Device,
        tops: &[SharedBlob],
        prop_down: &[bool],
        bottoms: &[SharedBlob],
    ) -> anyhow::Result<()> {
        if !prop_down.first().copied().unwrap_or(true) {
            return Ok(());
        }
        let (num, channels, dim) = self.dims;
        let b_id = bottoms[0].borrow_mut().data.dev_data(dev);
        let t_id = tops[0].borrow_mut().data.dev_data(dev);
        let s_id = self.scale.as_ref().unwrap().borrow_mut().data.dev_data(dev);
        let td_id = tops[0].borrow_mut().diff.dev_data(dev);
        let bd_id = bottoms[0].borrow_mut().diff.dev_data_mut(dev);
        dev.launch(&KernelCall::new(
            Kernel::LrnDiff {
                num,
                channels,
                dim,
                local_size: self.p.local_size,
                alpha: self.p.alpha,
                beta: self.p.beta,
            },
            &[b_id, t_id, s_id, td_id],
            &[bd_id],
        ))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::cpu::CpuDevice;

    #[test]
    fn forward_normalizes_and_backward_runs() {
        let mut dev = CpuDevice::new();
        let mut lp = LayerParameter::new("n", "LRN");
        lp.lrn = Some(LrnParameter { local_size: 3, alpha: 1.0, beta: 0.5, k: 1.0 });
        let mut layer = LrnLayer::new(&lp);
        let bottom = super::super::shared(Blob::new("x", &[1, 3, 1, 1]));
        let top = super::super::shared(Blob::new("y", &[1]));
        bottom.borrow_mut().set_data(&mut dev, &[3.0, 0.0, 4.0]);
        layer.setup(&mut dev, &[bottom.clone()], &[top.clone()]).unwrap();
        layer.forward(&mut dev, &[bottom.clone()], &[top.clone()]).unwrap();
        let out = top.borrow_mut().data_vec(&mut dev);
        // scale(c=1) = 1 + (1/3)(9+0+16) = 9.333; out1 = 0
        assert_eq!(out[1], 0.0);
        // scale(c=0) = 1 + (1/3)(9) = 4 → 3 * 4^-0.5 = 1.5
        assert!((out[0] - 1.5).abs() < 1e-5);
        top.borrow_mut().set_diff(&mut dev, &[1.0, 1.0, 1.0]);
        layer
            .backward(&mut dev, &[top], &[true], &[bottom.clone()])
            .unwrap();
        let bd = bottom.borrow_mut().diff_vec(&mut dev);
        assert!(bd.iter().all(|v| v.is_finite()));
        assert!(bd[0] != 0.0);
    }
}
