//! Worker pool: each worker thread owns one warm net replica bound to
//! its own device and drains the shared dispatch queue.
//!
//! `Net` is built on `Rc<RefCell<Blob>>` and cannot cross threads, so a
//! worker *builds* its replica inside the thread from the (Send)
//! `NetParameter` and adopts the engine's `WeightSnapshot` — the
//! `Arc`-shared host weights. Activations, scratch buffers and the
//! device are all private to the worker, which is what makes N workers
//! run forwards concurrently without any locking on the hot path.
//!
//! **Dynamic shapes**: the replica is built once at `max_batch` (warming
//! every grow-only activation to its high-water allocation), then
//! reshaped via `Net::reshape_batch` to each popped batch's *bucketed*
//! size (`runtime::plan::batch_bucket`: next power of two, capped at
//! `max_batch`). A partial batch therefore costs the FLOPs of its bucket
//! — at most 2× its filled rows — instead of a pad-to-`max_batch`
//! forward, and a lone request runs at batch 1 with no special-cased
//! second replica. Reshapes between consecutive batches of the same
//! bucket are free (no-op), and the bucket count bounds shape churn to
//! `log2(max_batch)+1` distinct execution shapes.
//!
//! **Weight hot-swap**: before executing each popped batch the worker
//! compares the engine's published weights version (one atomic load)
//! against the version its replica carries; on a mismatch it takes the
//! slot lock once, adopts the new snapshot, and only then serves.
//! Adoption is O(1) per blob (`Arc` attach), batches already popped
//! finish on the version they started with, and every response is
//! stamped with exactly the version that computed it.

use super::batcher::{gather, scatter, Batch};
use super::engine::{DeviceKind, SharedWeights};
use super::metrics::Metrics;
use super::queue::SharedQueue;
use crate::device::Device;
use crate::layers::{LayerTiming, SharedBlob};
use crate::net::{Net, WeightSnapshot};
use crate::obs::{BatchTraceBuilder, EngineObs, TraceScope, LANE_HOST, LANE_LAYER, LANE_QUEUE};
use crate::proto::Phase;
use crate::runtime::plan::batch_bucket;
use crate::zoo::DeployNet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

pub(crate) struct WorkerContext {
    pub id: usize,
    pub deploy: DeployNet,
    /// The engine's published-weights cell (version + snapshot slot).
    pub weights: Arc<SharedWeights>,
    pub device: DeviceKind,
    /// Intra-op threads this worker's kernels may fan out to (the
    /// engine's share of the process budget; see `util::pool`).
    pub intra_op: usize,
    /// Elements per output row (classes).
    pub output_len: usize,
    pub queue: Arc<SharedQueue<Batch>>,
    pub metrics: Arc<Metrics>,
    /// Sampled batch traces + per-layer aggregates (engine-wide).
    pub obs: Arc<EngineObs>,
    /// Workers still able to serve (shared across the pool).
    pub healthy: Arc<AtomicUsize>,
}

impl WorkerContext {
    /// Snapshot currently published by the engine (cloned `Arc`).
    fn current_weights(&self) -> Arc<WeightSnapshot> {
        self.weights.slot.lock().unwrap().clone()
    }
}

/// Retires the worker from `healthy` however the thread exits — clean
/// return, failed build, or panic mid-batch. The last worker out closes
/// and fail-drains the dispatch queue, so the batcher can never block
/// pushing into a dead pool and no caller hangs on a queued request.
struct PoolGuard {
    queue: Arc<SharedQueue<Batch>>,
    healthy: Arc<AtomicUsize>,
}

impl Drop for PoolGuard {
    fn drop(&mut self) {
        if self.healthy.fetch_sub(1, Ordering::AcqRel) > 1 {
            return; // healthy workers remain; they keep draining
        }
        self.queue.close();
        while let Some(batch) = self.queue.pop() {
            for req in batch.requests {
                req.fail("serving worker pool exhausted");
            }
        }
    }
}

/// The worker's single net replica, reshaped on the fly to each batch's
/// bucketed row count.
struct Replica {
    net: Net,
    input: SharedBlob,
    output: SharedBlob,
    /// Batch rows the net is currently shaped for.
    rows: usize,
}

impl Replica {
    /// Build at the deploy net's full `max_batch` shape, so every
    /// grow-only activation starts at its high-water allocation and no
    /// later reshape ever allocates on the serving path.
    fn build(
        ctx: &WorkerContext,
        snap: &WeightSnapshot,
        dev: &mut dyn Device,
    ) -> anyhow::Result<Replica> {
        anyhow::ensure!(
            !ctx.deploy.param.inputs.is_empty(),
            "deploy param has no inputs"
        );
        let mut net = Net::from_param(&ctx.deploy.param, Phase::Test, dev)?;
        net.adopt_weights(dev, snap)?;
        let input = net
            .blob(&ctx.deploy.input)
            .ok_or_else(|| anyhow::anyhow!("input blob '{}' missing", ctx.deploy.input))?;
        let output = net
            .blob(&ctx.deploy.output)
            .ok_or_else(|| anyhow::anyhow!("output blob '{}' missing", ctx.deploy.output))?;
        Ok(Replica { net, input, output, rows: ctx.deploy.batch })
    }

    /// Reshape to the batch's bucket, execute, and scatter the results,
    /// stamping every response with the weights version that computed it.
    ///
    /// When this batch is sampled (`obs.traces.begin()`), every stage is
    /// bracketed in spans, the forward runs per-layer traced, and the
    /// device profiler's pcie/fpga-kernel lanes are merged in — rebased
    /// from the simulated clock so the batch's first device operation
    /// lands at the host-side upload offset. Un-sampled batches pass
    /// `None` builders everywhere and pay no clock reads.
    fn serve(&mut self, dev: &mut dyn Device, batch: Batch, ctx: &WorkerContext, version: u64) {
        let k = batch.requests.len();
        let rows = batch_bucket(k, ctx.deploy.batch);
        // Sampled trace, origin = the oldest request's submit instant:
        // origin→`formed` is queue + linger wait, `formed`→now is
        // dispatch-queue wait until this worker popped the batch.
        let mut trace = ctx.obs.traces.begin().map(|seq| {
            let t0 = batch.requests.iter().map(|r| r.submitted).min().unwrap_or(batch.formed);
            let mut b = BatchTraceBuilder::new(seq, t0, k, version);
            b.set_rows(rows);
            b.span_between(LANE_QUEUE, "queue-wait", t0, batch.formed);
            b.span_between(LANE_QUEUE, "dispatch-wait", batch.formed, Instant::now());
            b
        });
        if rows != self.rows {
            let _s = TraceScope::start(trace.as_mut(), LANE_HOST, "reshape");
            if let Err(e) = self.net.reshape_batch(dev, rows) {
                // A failed reshape can leave the DAG half-propagated:
                // poison the cached shape so the next batch re-runs the
                // reshape instead of trusting a stale `rows` match.
                self.rows = 0;
                let msg = format!("worker {}: reshape to batch {rows} failed: {e:#}", ctx.id);
                for req in batch.requests {
                    req.fail(&msg);
                }
                return;
            }
            self.rows = rows;
        }
        let packed = {
            let _s = TraceScope::start(trace.as_mut(), LANE_HOST, "gather");
            let samples: Vec<&[f32]> =
                batch.requests.iter().map(|r| r.sample.as_slice()).collect();
            gather(&samples, ctx.deploy.sample_len, rows)
        };
        // Device lanes: turn span recording on for the sampled batch and
        // note where its device work begins, on both clocks — `dev_base`
        // on the batch timeline, `sim0` on the simulated clock.
        let mut dev_base = 0u64;
        if let Some(b) = trace.as_mut() {
            dev.set_span_recording(true);
            dev_base = b.offset_of(Instant::now());
        }
        let sim0 = dev.sim_clock_ns().unwrap_or(0);
        {
            let _s = TraceScope::start(trace.as_mut(), LANE_HOST, "upload");
            self.input.borrow_mut().set_data(dev, &packed);
        }
        // On the FPGA sim, meter the batch in *simulated* device time so
        // batching policy can be judged against the paper's cost model.
        let sim_before = dev.sim_clock_ns();
        let mut layer_rows: Vec<(String, u64, u64)> = Vec::new();
        let fwd = match trace.as_mut() {
            Some(b) => {
                let fwd_base = b.offset_of(Instant::now());
                let r = self.net.forward_traced(dev, &mut |t: LayerTiming<'_>| {
                    let start = fwd_base + t.wall_start_ns;
                    b.push(LANE_LAYER, t.name.to_string(), start, t.wall_ns.max(1));
                    layer_rows.push((t.name.to_string(), t.wall_ns, t.sim_ns.unwrap_or(0)));
                });
                let end = b.offset_of(Instant::now());
                let dur = end.saturating_sub(fwd_base).max(1);
                b.push(LANE_HOST, "forward".to_string(), fwd_base, dur);
                r
            }
            None => self.net.forward(dev),
        };
        match fwd {
            Ok(_) => {
                // Row accounting only for batches that actually ran —
                // a failed forward must not inflate occupancy.
                ctx.metrics.record_rows(k, rows);
                if let (Some(t0), Some(t1)) = (sim_before, dev.sim_clock_ns()) {
                    ctx.metrics.record_sim_batch(t1.saturating_sub(t0));
                }
                if !layer_rows.is_empty() {
                    ctx.obs.layers.record(&layer_rows);
                }
                // Read back only the filled rows — the grow-only output
                // blob's allocation is sized for the largest batch ever
                // run, not this one.
                let mut out = vec![0.0f32; k * ctx.output_len];
                {
                    let _s = TraceScope::start(trace.as_mut(), LANE_HOST, "readback");
                    self.output.borrow_mut().data.read_prefix(dev, &mut out);
                }
                // Merge the device lanes recorded across upload, forward
                // and readback, rebased onto the batch timeline.
                if let Some(b) = trace.as_mut() {
                    let spans = dev.take_spans();
                    dev.set_span_recording(false);
                    for s in spans {
                        let start = dev_base + s.start_ns.saturating_sub(sim0);
                        b.push(s.lane, s.name, start, s.dur_ns.max(1));
                    }
                }
                let result_rows = {
                    let _s = TraceScope::start(trace.as_mut(), LANE_HOST, "scatter");
                    scatter(&out, ctx.output_len, k)
                };
                {
                    let _s = TraceScope::start(trace.as_mut(), LANE_HOST, "respond");
                    for (req, row) in batch.requests.into_iter().zip(result_rows) {
                        let ns = req.submitted.elapsed().as_nanos() as u64;
                        req.fulfill(row, version);
                        ctx.metrics.record_done(ns);
                    }
                }
                if let Some(b) = trace.take() {
                    ctx.obs.traces.commit(b.finish());
                }
            }
            Err(e) => {
                if trace.is_some() {
                    // Leave the device clean for the next batch; the
                    // partial trace is dropped, never committed.
                    dev.set_span_recording(false);
                    let _ = dev.take_spans();
                }
                let msg = format!("worker {}: forward failed: {e:#}", ctx.id);
                for req in batch.requests {
                    req.fail(&msg);
                }
            }
        }
    }
}

pub(crate) fn run(ctx: WorkerContext) {
    let _guard = PoolGuard {
        queue: ctx.queue.clone(),
        healthy: ctx.healthy.clone(),
    };

    // This worker's share of the machine: everything executed on this
    // thread (replica build and every kernel) fans out at most
    // `intra_op` wide, so N workers never oversubscribe the pool.
    crate::util::pool::set_intra_op(ctx.intra_op);

    let mut dev: Box<dyn Device> = ctx.device.create();

    // Build the replica before taking traffic, so no net construction
    // (layer setup + weight-filler init) ever lands on the serving path.
    let snap = ctx.current_weights();
    let mut version = snap.version();
    let mut replica = match Replica::build(&ctx, &snap, dev.as_mut()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("[serve] worker {}: replica build failed: {e:#}", ctx.id);
            return;
        }
    };
    drop(snap);

    while let Some(batch) = ctx.queue.pop() {
        // Batch boundary: adopt a newly published snapshot before
        // executing. One relaxed-cost atomic load in the common case;
        // the slot lock is only taken when the version actually moved.
        // (The engine validated the snapshot against the shared schema,
        // so an adoption failure here indicates a bug, not bad input —
        // the worker keeps serving its current version.)
        if ctx.weights.version.load(Ordering::Acquire) != version {
            let snap = ctx.current_weights();
            match replica.net.adopt_weights(dev.as_mut(), &snap) {
                Ok(()) => version = snap.version(),
                Err(e) => {
                    eprintln!(
                        "[serve] worker {}: failed to adopt weights v{}: {e:#}; \
                         still serving v{version}",
                        ctx.id,
                        snap.version()
                    );
                }
            }
        }
        replica.serve(dev.as_mut(), batch, &ctx, version);
    }
}
