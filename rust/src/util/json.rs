//! Minimal JSON value model, writer and parser.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`, shared with
//! the python AOT side), chrome-trace output, and bench result logs. This
//! is a full small implementation (objects, arrays, strings with escapes,
//! numbers, bool, null) — no serde in the offline vendor set.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    // BTreeMap so emitted manifests are deterministically ordered.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(items: impl IntoIterator<Item = Json>) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Array of numbers helper (shape lists etc.).
    pub fn nums<T: Into<f64> + Copy>(items: &[T]) -> Json {
        Json::Arr(items.iter().map(|&v| Json::Num(v.into())).collect())
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty printer with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }

    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if u32::from(c) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", u32::from(c));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && self.b[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.pos)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-' {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| "invalid utf-8")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let mut o = Json::obj();
        o.set("name", Json::str("gemm_nn"))
            .set("flops", Json::num(1234.5))
            .set("shape", Json::nums(&[64i32, 576, 3136]))
            .set("fused", Json::Bool(true))
            .set("note", Json::Null);
        let text = o.to_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(o, back);
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#"{"s":"a\"b\\c\ndAé"}"#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "a\"b\\c\ndAé");
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::num(42).to_string(), "42");
        assert_eq!(Json::num(2.5).to_string(), "2.5");
        assert_eq!(Json::num(-0.0).to_string(), "0");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn nested_structures() {
        let text = r#"{"a":[{"b":[1,2,[3]]},{"c":{"d":null}}],"e":-1.5e3}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("e").unwrap().as_f64().unwrap(), -1500.0);
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 2);
        let reparsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, reparsed);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::obj().to_string(), "{}");
    }
}
