//! ReLU layer (kernels `ReLU_F` / `ReLU_B`), in-place capable like
//! Caffe's — GoogLeNet's prototxt uses in-place ReLU everywhere.

use super::{Layer, SharedBlob};
use crate::device::{Device, Kernel, KernelCall};
use crate::proto::LayerParameter;
use std::rc::Rc;

pub struct ReluLayer {
    name: String,
    slope: f32,
    count: usize,
}

impl ReluLayer {
    pub fn new(param: &LayerParameter) -> ReluLayer {
        ReluLayer { name: param.name.clone(), slope: 0.0, count: 0 }
    }
}

impl Layer for ReluLayer {
    fn name(&self) -> &str {
        &self.name
    }
    fn kind(&self) -> &'static str {
        "ReLU"
    }

    fn setup(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        self.reshape(dev, bottoms, tops)
    }

    fn reshape(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        self.count = bottoms[0].borrow().count();
        if !Rc::ptr_eq(&bottoms[0], &tops[0]) {
            let shape = bottoms[0].borrow().shape().to_vec();
            tops[0].borrow_mut().reshape_grow_only(dev, &shape);
        }
        Ok(())
    }

    fn forward(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<f32> {
        let in_place = Rc::ptr_eq(&bottoms[0], &tops[0]);
        if in_place {
            let mut b = bottoms[0].borrow_mut();
            let id = b.data.dev_data_rw(dev);
            dev.launch(&KernelCall::new(
                Kernel::ReluF { n: self.count, slope: self.slope },
                &[id],
                &[id],
            ))?;
        } else {
            let b_id = bottoms[0].borrow_mut().data.dev_data(dev);
            let t_id = tops[0].borrow_mut().data.dev_data_mut(dev);
            dev.launch(&KernelCall::new(
                Kernel::ReluF { n: self.count, slope: self.slope },
                &[b_id],
                &[t_id],
            ))?;
        }
        Ok(0.0)
    }

    fn backward(
        &mut self,
        dev: &mut dyn Device,
        tops: &[SharedBlob],
        prop_down: &[bool],
        bottoms: &[SharedBlob],
    ) -> anyhow::Result<()> {
        if !prop_down.first().copied().unwrap_or(true) {
            return Ok(());
        }
        // NOTE on in-place: bottom data was overwritten by forward, but
        // relu'd data has the same sign pattern (x>0 ⇔ relu(x)>0 for
        // slope 0), so Caffe's in-place relu backward stays exact.
        let in_place = Rc::ptr_eq(&bottoms[0], &tops[0]);
        if in_place {
            let mut b = bottoms[0].borrow_mut();
            let data_id = b.data.dev_data(dev);
            let diff_id = b.diff.dev_data_rw(dev);
            dev.launch(&KernelCall::new(
                Kernel::ReluB { n: self.count, slope: self.slope },
                &[data_id, diff_id],
                &[diff_id],
            ))?;
        } else {
            let b_data = bottoms[0].borrow_mut().data.dev_data(dev);
            let t_diff = tops[0].borrow_mut().diff.dev_data(dev);
            let b_diff = bottoms[0].borrow_mut().diff.dev_data_mut(dev);
            dev.launch(&KernelCall::new(
                Kernel::ReluB { n: self.count, slope: self.slope },
                &[b_data, t_diff],
                &[b_diff],
            ))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::Blob;
    use crate::device::cpu::CpuDevice;

    #[test]
    fn separate_top_forward_backward() {
        let mut dev = CpuDevice::new();
        let mut layer = ReluLayer::new(&LayerParameter::new("r", "ReLU"));
        let bottom = super::super::shared(Blob::new("x", &[4]));
        let top = super::super::shared(Blob::new("y", &[4]));
        bottom.borrow_mut().set_data(&mut dev, &[-1.0, 2.0, -3.0, 4.0]);
        layer.setup(&mut dev, &[bottom.clone()], &[top.clone()]).unwrap();
        layer.forward(&mut dev, &[bottom.clone()], &[top.clone()]).unwrap();
        assert_eq!(top.borrow_mut().data_vec(&mut dev), vec![0.0, 2.0, 0.0, 4.0]);
        top.borrow_mut().set_diff(&mut dev, &[1.0; 4]);
        layer
            .backward(&mut dev, &[top], &[true], &[bottom.clone()])
            .unwrap();
        assert_eq!(
            bottom.borrow_mut().diff_vec(&mut dev),
            vec![0.0, 1.0, 0.0, 1.0]
        );
    }

    #[test]
    fn in_place_roundtrip() {
        let mut dev = CpuDevice::new();
        let mut layer = ReluLayer::new(&LayerParameter::new("r", "ReLU"));
        let blob = super::super::shared(Blob::new("x", &[3]));
        blob.borrow_mut().set_data(&mut dev, &[-1.0, 0.5, 2.0]);
        layer.setup(&mut dev, &[blob.clone()], &[blob.clone()]).unwrap();
        layer.forward(&mut dev, &[blob.clone()], &[blob.clone()]).unwrap();
        assert_eq!(blob.borrow_mut().data_vec(&mut dev), vec![0.0, 0.5, 2.0]);
        blob.borrow_mut().set_diff(&mut dev, &[5.0, 5.0, 5.0]);
        layer
            .backward(&mut dev, &[blob.clone()], &[true], &[blob.clone()])
            .unwrap();
        // data after forward: [0, .5, 2] → gradient passes where data > 0
        assert_eq!(blob.borrow_mut().diff_vec(&mut dev), vec![0.0, 5.0, 5.0]);
    }
}
