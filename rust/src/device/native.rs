//! Buffer slab + native kernel executor.
//!
//! Shared by the CPU fallback device and by the FPGA simulator (which uses
//! it for data-movement kernels and as the numerical engine when a PJRT
//! artifact is deliberately not generated for a shape — the timing it
//! bills is the cost model's either way).

use super::{BufId, Kernel, KernelCall};
use crate::math;

/// Slab of f32 buffers with freelist reuse.
#[derive(Debug, Default)]
pub struct Slab {
    bufs: Vec<Option<Vec<f32>>>,
    free: Vec<usize>,
}

impl Slab {
    pub fn new() -> Slab {
        Slab::default()
    }

    pub fn alloc(&mut self, len: usize) -> BufId {
        match self.free.pop() {
            Some(i) => {
                let v = self.bufs[i].as_mut().expect("freelist slot must exist");
                v.clear();
                v.resize(len, 0.0);
                BufId(i)
            }
            None => {
                self.bufs.push(Some(vec![0.0; len]));
                BufId(self.bufs.len() - 1)
            }
        }
    }

    pub fn free(&mut self, id: BufId) {
        assert!(self.bufs[id.0].is_some(), "double free of {id:?}");
        // Keep allocation for reuse; mark slot free.
        self.free.push(id.0);
    }

    pub fn len_of(&self, id: BufId) -> usize {
        self.bufs[id.0].as_ref().expect("freed buffer").len()
    }

    pub fn get(&self, id: BufId) -> &[f32] {
        self.bufs[id.0].as_ref().expect("freed buffer")
    }

    pub fn get_mut(&mut self, id: BufId) -> &mut [f32] {
        self.bufs[id.0].as_mut().expect("freed buffer")
    }

    fn take(&mut self, id: BufId) -> Vec<f32> {
        self.bufs[id.0].take().expect("freed buffer")
    }

    fn put(&mut self, id: BufId, v: Vec<f32>) {
        debug_assert!(self.bufs[id.0].is_none());
        self.bufs[id.0] = Some(v);
    }

    pub fn live_buffers(&self) -> usize {
        self.bufs.len() - self.free.len()
    }
}

/// Execute a kernel call against the slab with native math.
///
/// Aliasing: an output id may equal an input id (in-place ops). Each
/// output buffer is `take`n exactly once; inputs that alias a taken
/// output are served from the taken vector.
pub fn execute(slab: &mut Slab, call: &KernelCall) -> anyhow::Result<()> {
    use Kernel::*;

    // Take all (distinct) outputs out of the slab.
    let mut out_bufs: Vec<(BufId, Vec<f32>)> = Vec::with_capacity(call.outputs.len());
    for &oid in &call.outputs {
        if out_bufs.iter().any(|(id, _)| *id == oid) {
            anyhow::bail!("duplicate output buffer {oid:?}");
        }
        out_bufs.push((oid, slab.take(oid)));
    }
    // Inputs: clone aliased ones (rare: in-place eltwise), BORROW the
    // rest straight from the slab — outputs were moved out above, so the
    // borrows cannot alias (§Perf: the previous clone-everything version
    // cost one multi-MB allocation+copy per gemm launch).
    enum In<'a> {
        Borrowed(&'a [f32]),
        Owned(Vec<f32>),
    }
    let input_data: Vec<In> = call
        .inputs
        .iter()
        .zip(call.in_offsets.iter())
        .map(|(iid, off)| {
            if let Some((_, v)) = out_bufs.iter().find(|(oid, _)| oid == iid) {
                In::Owned(v[*off..].to_vec())
            } else {
                In::Borrowed(&slab.get(*iid)[*off..])
            }
        })
        .collect();
    let inp = |i: usize| -> &[f32] {
        match &input_data[i] {
            In::Borrowed(s) => s,
            In::Owned(v) => v,
        }
    };
    let result = (|| -> anyhow::Result<()> {
        macro_rules! out {
            ($i:expr) => {
                &mut out_bufs[$i].1[call.out_offsets[$i]..]
            };
        }
        match &call.kernel {
            GemmNN { m, n, k, alpha, beta } => math::gemm(
                math::Trans::No,
                math::Trans::No,
                *m,
                *n,
                *k,
                *alpha,
                inp(0),
                inp(1),
                *beta,
                out!(0),
            ),
            GemmNT { m, n, k, alpha, beta } => math::gemm(
                math::Trans::No,
                math::Trans::Yes,
                *m,
                *n,
                *k,
                *alpha,
                inp(0),
                inp(1),
                *beta,
                out!(0),
            ),
            GemmTN { m, n, k, alpha, beta } => math::gemm(
                math::Trans::Yes,
                math::Trans::No,
                *m,
                *n,
                *k,
                *alpha,
                inp(0),
                inp(1),
                *beta,
                out!(0),
            ),
            Gemv { trans, m, n, alpha, beta } => math::gemv(
                if *trans { math::Trans::Yes } else { math::Trans::No },
                *m,
                *n,
                *alpha,
                inp(0),
                inp(1),
                *beta,
                out!(0),
            ),
            Axpy { n, alpha } => math::axpy(*alpha, &inp(0)[..*n], &mut out!(0)[..*n]),
            Axpby { n, alpha, beta } => {
                math::axpby(*alpha, &inp(0)[..*n], *beta, &mut out!(0)[..*n]);
            }
            Scal { n, alpha } => math::scal(*alpha, &mut out!(0)[..*n]),
            Asum { n } => {
                let s = math::asum(&inp(0)[..*n]);
                out!(0)[0] = s;
            }
            Add { n } => math::add(&inp(0)[..*n], &inp(1)[..*n], &mut out!(0)[..*n]),
            Mul { n } => math::mul(&inp(0)[..*n], &inp(1)[..*n], &mut out!(0)[..*n]),
            PowX { n, p } => math::powx(&inp(0)[..*n], *p, &mut out!(0)[..*n]),
            SetConst { n, value } => math::set(&mut out!(0)[..*n], *value),
            Split { n } => math::axpy(1.0, &inp(0)[..*n], &mut out!(0)[..*n]),
            Im2col { geom } => math::im2col(geom, inp(0), out!(0)),
            Col2im { geom } => math::col2im(geom, inp(0), out!(0)),
            MaxPoolF { geom, num } => {
                // top=0, mask=1 — whole batch, images sharded in the math
                // layer across the intra-op pool.
                let (il, ol) = (geom.in_len(), geom.out_len());
                let (ot, om) = (call.out_offsets[0], call.out_offsets[1]);
                let (top_pair, mask_pair) = out_bufs.split_at_mut(1);
                math::max_pool_forward_batch(
                    geom,
                    *num,
                    &inp(0)[..*num * il],
                    &mut top_pair[0].1[ot..ot + *num * ol],
                    &mut mask_pair[0].1[om..om + *num * ol],
                );
            }
            MaxPoolB { geom, num } => {
                let ol = geom.out_len();
                math::max_pool_backward_batch(
                    geom,
                    *num,
                    &inp(0)[..*num * ol],
                    &inp(1)[..*num * ol],
                    &mut out_bufs[0].1[call.out_offsets[0]..],
                );
            }
            AvePoolF { geom, num } => {
                let (il, ol) = (geom.in_len(), geom.out_len());
                let ot = call.out_offsets[0];
                math::ave_pool_forward_batch(
                    geom,
                    *num,
                    &inp(0)[..*num * il],
                    &mut out_bufs[0].1[ot..ot + *num * ol],
                );
            }
            AvePoolB { geom, num } => {
                let ol = geom.out_len();
                math::ave_pool_backward_batch(
                    geom,
                    *num,
                    &inp(0)[..*num * ol],
                    &mut out_bufs[0].1[call.out_offsets[0]..],
                );
            }
            ReluF { n, slope } => {
                math::relu_forward(&inp(0)[..*n], &mut out!(0)[..*n], *slope);
            }
            ReluB { n, slope } => math::relu_backward(
                &inp(0)[..*n],
                &inp(1)[..*n],
                &mut out!(0)[..*n],
                *slope,
            ),
            LrnScale { num, channels, dim, local_size, alpha, k } => {
                let plane = channels * dim;
                let ot = call.out_offsets[0];
                math::lrn_scale_batch(
                    *num,
                    &inp(0)[..*num * plane],
                    &mut out_bufs[0].1[ot..ot + *num * plane],
                    *channels,
                    *dim,
                    *local_size,
                    *alpha,
                    *k,
                );
            }
            LrnOutput { n, beta } => {
                math::lrn_output(&inp(0)[..*n], &inp(1)[..*n], &mut out!(0)[..*n], *beta);
            }
            LrnDiff { num, channels, dim, local_size, alpha, beta } => {
                let plane = channels * dim;
                let o = call.out_offsets[0];
                math::lrn_diff_batch(
                    *num,
                    inp(0),
                    inp(1),
                    inp(2),
                    inp(3),
                    &mut out_bufs[0].1[o..o + *num * plane],
                    *channels,
                    *dim,
                    *local_size,
                    *alpha,
                    *beta,
                );
            }
            DropoutF { n, scale } => math::dropout_forward(
                &inp(0)[..*n],
                &inp(1)[..*n],
                *scale,
                &mut out!(0)[..*n],
            ),
            DropoutB { n, scale } => math::dropout_backward(
                &inp(0)[..*n],
                &inp(1)[..*n],
                *scale,
                &mut out!(0)[..*n],
            ),
            BiasF { outer, channels, dim } => {
                math::bias_forward(&mut out!(0)[..outer * channels * dim], &inp(0)[..*channels], *outer, *channels, *dim);
            }
            SoftmaxF { n, c } => math::softmax_forward(inp(0), out!(0), *n, *c),
            SoftmaxLossF { n, c } => {
                let l = math::softmax_loss_forward(inp(0), inp(1), *n, *c);
                out!(0)[0] = l;
            }
            SoftmaxLossB { n, c, weight } => {
                math::softmax_loss_backward(inp(0), inp(1), out!(0), *n, *c, *weight);
            }
            ConcatF { num, this, total, offset } => {
                for i in 0..*num {
                    let src = &inp(0)[i * this..(i + 1) * this];
                    out!(0)[i * total + offset..i * total + offset + this]
                        .copy_from_slice(src);
                }
            }
            ConcatB { num, this, total, offset } => {
                for i in 0..*num {
                    let src =
                        &inp(0)[i * total + offset..i * total + offset + this];
                    out!(0)[i * this..(i + 1) * this].copy_from_slice(src);
                }
            }
            SgdUpdate { n, lr, momentum } => {
                // out: [hist, data]; in: [diff]
                let diff = inp(0);
                let (h, d) = out_bufs.split_at_mut(1);
                let hist = &mut h[0].1[call.out_offsets[0]..];
                let data = &mut d[0].1[call.out_offsets[1]..];
                for i in 0..*n {
                    hist[i] = momentum * hist[i] + lr * diff[i];
                    data[i] -= hist[i];
                }
            }
            NesterovUpdate { n, lr, momentum } => {
                let diff = inp(0);
                let (h, d) = out_bufs.split_at_mut(1);
                let hist = &mut h[0].1[call.out_offsets[0]..];
                let data = &mut d[0].1[call.out_offsets[1]..];
                for i in 0..*n {
                    let h_old = hist[i];
                    hist[i] = momentum * h_old + lr * diff[i];
                    data[i] -= (1.0 + momentum) * hist[i] - momentum * h_old;
                }
            }
            AdaGradUpdate { n, lr, delta } => {
                let diff = inp(0);
                let (h, d) = out_bufs.split_at_mut(1);
                let hist = &mut h[0].1[call.out_offsets[0]..];
                let data = &mut d[0].1[call.out_offsets[1]..];
                for i in 0..*n {
                    hist[i] += diff[i] * diff[i];
                    data[i] -= lr * diff[i] / (hist[i].sqrt() + delta);
                }
            }
            RmsPropUpdate { n, lr, decay, delta } => {
                let diff = inp(0);
                let (h, d) = out_bufs.split_at_mut(1);
                let hist = &mut h[0].1[call.out_offsets[0]..];
                let data = &mut d[0].1[call.out_offsets[1]..];
                for i in 0..*n {
                    hist[i] = decay * hist[i] + (1.0 - decay) * diff[i] * diff[i];
                    data[i] -= lr * diff[i] / (hist[i].sqrt() + delta);
                }
            }
            AdaDeltaUpdate { n, momentum, delta, lr } => {
                // out: [hist_grad2, hist_update2, data]; in: [diff]
                let diff = inp(0);
                let (h1, rest) = out_bufs.split_at_mut(1);
                let (h2, d) = rest.split_at_mut(1);
                let hg = &mut h1[0].1[call.out_offsets[0]..];
                let hu = &mut h2[0].1[call.out_offsets[1]..];
                let data = &mut d[0].1[call.out_offsets[2]..];
                for i in 0..*n {
                    hg[i] = momentum * hg[i] + (1.0 - momentum) * diff[i] * diff[i];
                    let update =
                        diff[i] * ((hu[i] + delta) / (hg[i] + delta)).sqrt();
                    hu[i] = momentum * hu[i] + (1.0 - momentum) * update * update;
                    data[i] -= lr * update;
                }
            }
            AdamUpdate { n, lr, beta1, beta2, delta, t } => {
                // out: [m, v, data]; in: [diff]
                let diff = inp(0);
                let (m1, rest) = out_bufs.split_at_mut(1);
                let (v1, d) = rest.split_at_mut(1);
                let m = &mut m1[0].1[call.out_offsets[0]..];
                let v = &mut v1[0].1[call.out_offsets[1]..];
                let data = &mut d[0].1[call.out_offsets[2]..];
                let t = *t as i32;
                let correction =
                    (1.0 - beta2.powi(t)).sqrt() / (1.0 - beta1.powi(t));
                for i in 0..*n {
                    m[i] = beta1 * m[i] + (1.0 - beta1) * diff[i];
                    v[i] = beta2 * v[i] + (1.0 - beta2) * diff[i] * diff[i];
                    data[i] -= lr * correction * m[i] / (v[i].sqrt() + delta);
                }
            }
        }
        Ok(())
    })();

    // Restore outputs.
    for (id, v) in out_bufs {
        slab.put(id, v);
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{BufId, Kernel, KernelCall};

    fn slab_with(vals: &[&[f32]]) -> (Slab, Vec<BufId>) {
        let mut s = Slab::new();
        let ids = vals
            .iter()
            .map(|v| {
                let id = s.alloc(v.len());
                s.get_mut(id).copy_from_slice(v);
                id
            })
            .collect();
        (s, ids)
    }

    #[test]
    fn slab_alloc_free_reuse() {
        let mut s = Slab::new();
        let a = s.alloc(4);
        let b = s.alloc(8);
        assert_ne!(a, b);
        assert_eq!(s.live_buffers(), 2);
        s.free(a);
        assert_eq!(s.live_buffers(), 1);
        let c = s.alloc(2);
        assert_eq!(c, a, "freelist should reuse slot");
        assert_eq!(s.get(c), &[0.0, 0.0], "reused buffer must be zeroed/resized");
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn slab_double_free_panics() {
        let mut s = Slab::new();
        let a = s.alloc(1);
        s.free(a);
        // double-free detected because the slot is vacated only on take;
        // freeing twice pushes a duplicate — catch via debug check
        s.bufs[a.0] = None;
        s.free(a);
    }

    #[test]
    fn gemm_call() {
        let (mut s, ids) = slab_with(&[&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], &[0.0; 4]]);
        let call = KernelCall::new(
            Kernel::GemmNN { m: 2, n: 2, k: 2, alpha: 1.0, beta: 0.0 },
            &[ids[0], ids[1]],
            &[ids[2]],
        );
        execute(&mut s, &call).unwrap();
        assert_eq!(s.get(ids[2]), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn in_place_relu() {
        let (mut s, ids) = slab_with(&[&[-1.0, 2.0]]);
        let call = KernelCall::new(
            Kernel::ReluF { n: 2, slope: 0.0 },
            &[ids[0]],
            &[ids[0]],
        );
        execute(&mut s, &call).unwrap();
        assert_eq!(s.get(ids[0]), &[0.0, 2.0]);
    }

    #[test]
    fn sgd_update_call() {
        let (mut s, ids) = slab_with(&[&[1.0, 1.0], &[0.5, 0.0], &[10.0, 10.0]]);
        let call = KernelCall::new(
            Kernel::SgdUpdate { n: 2, lr: 0.1, momentum: 0.9 },
            &[ids[0]],
            &[ids[1], ids[2]],
        );
        execute(&mut s, &call).unwrap();
        // hist = 0.9*[0.5,0] + 0.1*[1,1] = [0.55, 0.1]; data = 10 - hist
        assert_eq!(s.get(ids[1]), &[0.55, 0.1]);
        assert_eq!(s.get(ids[2]), &[9.45, 9.9]);
    }

    #[test]
    fn concat_roundtrip() {
        // two inputs of 2 channels each (dim 1), num=2
        let (mut s, ids) = slab_with(&[
            &[1.0, 2.0, 5.0, 6.0],   // bottom0: n0=[1,2], n1=[5,6]
            &[3.0, 4.0, 7.0, 8.0],   // bottom1
            &[0.0; 8],               // top
        ]);
        for (i, &b) in [ids[0], ids[1]].iter().enumerate() {
            let call = KernelCall::new(
                Kernel::ConcatF { num: 2, this: 2, total: 4, offset: i * 2 },
                &[b],
                &[ids[2]],
            );
            execute(&mut s, &call).unwrap();
        }
        assert_eq!(
            s.get(ids[2]),
            &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]
        );
        // de-concat back out
        let back = s.alloc(4);
        let call = KernelCall::new(
            Kernel::ConcatB { num: 2, this: 2, total: 4, offset: 2 },
            &[ids[2]],
            &[back],
        );
        execute(&mut s, &call).unwrap();
        assert_eq!(s.get(back), &[3.0, 4.0, 7.0, 8.0]);
    }

    #[test]
    fn asum_writes_scalar() {
        let (mut s, ids) = slab_with(&[&[1.0, -2.0, 3.0], &[0.0]]);
        execute(
            &mut s,
            &KernelCall::new(Kernel::Asum { n: 3 }, &[ids[0]], &[ids[1]]),
        )
        .unwrap();
        assert_eq!(s.get(ids[1])[0], 6.0);
    }

    #[test]
    fn adam_bias_correction_first_step() {
        let (mut s, ids) = slab_with(&[&[1.0], &[0.0], &[0.0], &[1.0]]);
        execute(
            &mut s,
            &KernelCall::new(
                Kernel::AdamUpdate {
                    n: 1,
                    lr: 0.1,
                    beta1: 0.9,
                    beta2: 0.999,
                    delta: 1e-8,
                    t: 1,
                },
                &[ids[0]],
                &[ids[1], ids[2], ids[3]],
            ),
        )
        .unwrap();
        // m=0.1, v=0.001, corr=sqrt(0.001)/0.1; update = lr*corr*m/(sqrt(v)+d) ≈ lr
        let d = s.get(ids[3])[0];
        assert!((d - 0.9).abs() < 1e-4, "data after one adam step {d}");
    }
}
