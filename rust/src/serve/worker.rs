//! Worker pool: each worker thread owns one warm net replica bound to
//! its own device and drains the shared dispatch queue.
//!
//! `Net` is built on `Rc<RefCell<Blob>>` and cannot cross threads, so a
//! worker *builds* its replica inside the thread from the (Send)
//! `NetParameter` and adopts the engine's `WeightSnapshot` — the
//! `Arc`-shared host weights. Activations, scratch buffers and the
//! device are all private to the worker, which is what makes N workers
//! run forwards concurrently without any locking on the hot path.
//!
//! **Dynamic shapes**: the replica is built once at `max_batch` (warming
//! every grow-only activation to its high-water allocation), then
//! reshaped via `Net::reshape_batch` to each popped batch's *bucketed*
//! size (`runtime::plan::batch_bucket`: next power of two, capped at
//! `max_batch`). A partial batch therefore costs the FLOPs of its bucket
//! — at most 2× its filled rows — instead of a pad-to-`max_batch`
//! forward, and a lone request runs at batch 1 with no special-cased
//! second replica. Reshapes between consecutive batches of the same
//! bucket are free (no-op), and the bucket count bounds shape churn to
//! `log2(max_batch)+1` distinct execution shapes.
//!
//! **Weight hot-swap**: before executing each popped batch the worker
//! compares the engine's published weights version (one atomic load)
//! against the version its replica carries; on a mismatch it takes the
//! slot lock once, adopts the new snapshot, and only then serves.
//! Adoption is O(1) per blob (`Arc` attach), batches already popped
//! finish on the version they started with, and every response is
//! stamped with exactly the version that computed it.

use super::batcher::{gather, scatter, Batch};
use super::engine::{DeviceKind, SharedWeights};
use super::metrics::Metrics;
use super::queue::SharedQueue;
use crate::device::Device;
use crate::layers::SharedBlob;
use crate::net::{Net, WeightSnapshot};
use crate::proto::Phase;
use crate::runtime::plan::batch_bucket;
use crate::zoo::DeployNet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

pub(crate) struct WorkerContext {
    pub id: usize,
    pub deploy: DeployNet,
    /// The engine's published-weights cell (version + snapshot slot).
    pub weights: Arc<SharedWeights>,
    pub device: DeviceKind,
    /// Intra-op threads this worker's kernels may fan out to (the
    /// engine's share of the process budget; see `util::pool`).
    pub intra_op: usize,
    /// Elements per output row (classes).
    pub output_len: usize,
    pub queue: Arc<SharedQueue<Batch>>,
    pub metrics: Arc<Metrics>,
    /// Workers still able to serve (shared across the pool).
    pub healthy: Arc<AtomicUsize>,
}

impl WorkerContext {
    /// Snapshot currently published by the engine (cloned `Arc`).
    fn current_weights(&self) -> Arc<WeightSnapshot> {
        self.weights.slot.lock().unwrap().clone()
    }
}

/// Retires the worker from `healthy` however the thread exits — clean
/// return, failed build, or panic mid-batch. The last worker out closes
/// and fail-drains the dispatch queue, so the batcher can never block
/// pushing into a dead pool and no caller hangs on a queued request.
struct PoolGuard {
    queue: Arc<SharedQueue<Batch>>,
    healthy: Arc<AtomicUsize>,
}

impl Drop for PoolGuard {
    fn drop(&mut self) {
        if self.healthy.fetch_sub(1, Ordering::AcqRel) > 1 {
            return; // healthy workers remain; they keep draining
        }
        self.queue.close();
        while let Some(batch) = self.queue.pop() {
            for req in batch.requests {
                req.fail("serving worker pool exhausted");
            }
        }
    }
}

/// The worker's single net replica, reshaped on the fly to each batch's
/// bucketed row count.
struct Replica {
    net: Net,
    input: SharedBlob,
    output: SharedBlob,
    /// Batch rows the net is currently shaped for.
    rows: usize,
}

impl Replica {
    /// Build at the deploy net's full `max_batch` shape, so every
    /// grow-only activation starts at its high-water allocation and no
    /// later reshape ever allocates on the serving path.
    fn build(
        ctx: &WorkerContext,
        snap: &WeightSnapshot,
        dev: &mut dyn Device,
    ) -> anyhow::Result<Replica> {
        anyhow::ensure!(
            !ctx.deploy.param.inputs.is_empty(),
            "deploy param has no inputs"
        );
        let mut net = Net::from_param(&ctx.deploy.param, Phase::Test, dev)?;
        net.adopt_weights(dev, snap)?;
        let input = net
            .blob(&ctx.deploy.input)
            .ok_or_else(|| anyhow::anyhow!("input blob '{}' missing", ctx.deploy.input))?;
        let output = net
            .blob(&ctx.deploy.output)
            .ok_or_else(|| anyhow::anyhow!("output blob '{}' missing", ctx.deploy.output))?;
        Ok(Replica { net, input, output, rows: ctx.deploy.batch })
    }

    /// Reshape to the batch's bucket, execute, and scatter the results,
    /// stamping every response with the weights version that computed it.
    fn serve(&mut self, dev: &mut dyn Device, batch: Batch, ctx: &WorkerContext, version: u64) {
        let k = batch.requests.len();
        let rows = batch_bucket(k, ctx.deploy.batch);
        if rows != self.rows {
            if let Err(e) = self.net.reshape_batch(dev, rows) {
                // A failed reshape can leave the DAG half-propagated:
                // poison the cached shape so the next batch re-runs the
                // reshape instead of trusting a stale `rows` match.
                self.rows = 0;
                let msg = format!("worker {}: reshape to batch {rows} failed: {e:#}", ctx.id);
                for req in batch.requests {
                    req.fail(&msg);
                }
                return;
            }
            self.rows = rows;
        }
        let samples: Vec<&[f32]> =
            batch.requests.iter().map(|r| r.sample.as_slice()).collect();
        let packed = gather(&samples, ctx.deploy.sample_len, rows);
        drop(samples);
        self.input.borrow_mut().set_data(dev, &packed);
        // On the FPGA sim, meter the batch in *simulated* device time so
        // batching policy can be judged against the paper's cost model.
        let sim_before = dev.sim_clock_ns();
        match self.net.forward(dev) {
            Ok(_) => {
                // Row accounting only for batches that actually ran —
                // a failed forward must not inflate occupancy.
                ctx.metrics.record_rows(k, rows);
                if let (Some(t0), Some(t1)) = (sim_before, dev.sim_clock_ns()) {
                    ctx.metrics.record_sim_batch(t1.saturating_sub(t0));
                }
                // Read back only the filled rows — the grow-only output
                // blob's allocation is sized for the largest batch ever
                // run, not this one.
                let mut out = vec![0.0f32; k * ctx.output_len];
                self.output.borrow_mut().data.read_prefix(dev, &mut out);
                let result_rows = scatter(&out, ctx.output_len, k);
                for (req, row) in batch.requests.into_iter().zip(result_rows) {
                    let ns = req.submitted.elapsed().as_nanos() as u64;
                    req.fulfill(row, version);
                    ctx.metrics.record_done(ns);
                }
            }
            Err(e) => {
                let msg = format!("worker {}: forward failed: {e:#}", ctx.id);
                for req in batch.requests {
                    req.fail(&msg);
                }
            }
        }
    }
}

pub(crate) fn run(ctx: WorkerContext) {
    let _guard = PoolGuard {
        queue: ctx.queue.clone(),
        healthy: ctx.healthy.clone(),
    };

    // This worker's share of the machine: everything executed on this
    // thread (replica build and every kernel) fans out at most
    // `intra_op` wide, so N workers never oversubscribe the pool.
    crate::util::pool::set_intra_op(ctx.intra_op);

    let mut dev: Box<dyn Device> = ctx.device.create();

    // Build the replica before taking traffic, so no net construction
    // (layer setup + weight-filler init) ever lands on the serving path.
    let snap = ctx.current_weights();
    let mut version = snap.version();
    let mut replica = match Replica::build(&ctx, &snap, dev.as_mut()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("[serve] worker {}: replica build failed: {e:#}", ctx.id);
            return;
        }
    };
    drop(snap);

    while let Some(batch) = ctx.queue.pop() {
        // Batch boundary: adopt a newly published snapshot before
        // executing. One relaxed-cost atomic load in the common case;
        // the slot lock is only taken when the version actually moved.
        // (The engine validated the snapshot against the shared schema,
        // so an adoption failure here indicates a bug, not bad input —
        // the worker keeps serving its current version.)
        if ctx.weights.version.load(Ordering::Acquire) != version {
            let snap = ctx.current_weights();
            match replica.net.adopt_weights(dev.as_mut(), &snap) {
                Ok(()) => version = snap.version(),
                Err(e) => {
                    eprintln!(
                        "[serve] worker {}: failed to adopt weights v{}: {e:#}; \
                         still serving v{version}",
                        ctx.id,
                        snap.version()
                    );
                }
            }
        }
        replica.serve(dev.as_mut(), batch, &ctx, version);
    }
}
