//! Generic protobuf-text message tree.
//!
//! Field order is preserved and repeated fields are natural — exactly the
//! semantics Caffe relies on (e.g. repeated `layer { ... }` entries define
//! the network's topological intent and `top`/`bottom` repeat).

use super::lexer::{Tok, Token};

#[derive(Debug, Clone, PartialEq)]
pub enum PValue {
    Str(String),
    Num(f64),
    /// Bare identifiers: enum values (`MAX`, `TRAIN`) and booleans.
    Ident(String),
    Msg(PMessage),
}

#[derive(Debug, Clone, PartialEq, Default)]
pub struct PMessage {
    /// (field name, value) in source order; repeated fields appear multiple
    /// times.
    pub fields: Vec<(String, PValue)>,
}

impl PMessage {
    /// First value of a field.
    pub fn get(&self, name: &str) -> Option<&PValue> {
        self.fields.iter().find(|(n, _)| n == name).map(|(_, v)| v)
    }

    /// All values of a repeated field.
    pub fn get_all<'a>(&'a self, name: &'a str) -> impl Iterator<Item = &'a PValue> {
        self.fields
            .iter()
            .filter(move |(n, _)| n == name)
            .map(|(_, v)| v)
    }

    pub fn get_str(&self, name: &str) -> Option<&str> {
        match self.get(name) {
            Some(PValue::Str(s)) => Some(s),
            Some(PValue::Ident(s)) => Some(s),
            _ => None,
        }
    }

    pub fn get_num(&self, name: &str) -> Option<f64> {
        match self.get(name) {
            Some(PValue::Num(n)) => Some(*n),
            _ => None,
        }
    }

    pub fn get_u(&self, name: &str) -> Option<usize> {
        self.get_num(name).map(|n| n as usize)
    }

    pub fn get_bool(&self, name: &str) -> Option<bool> {
        match self.get(name) {
            Some(PValue::Ident(s)) => match s.as_str() {
                "true" => Some(true),
                "false" => Some(false),
                _ => None,
            },
            _ => None,
        }
    }

    pub fn get_msg(&self, name: &str) -> Option<&PMessage> {
        match self.get(name) {
            Some(PValue::Msg(m)) => Some(m),
            _ => None,
        }
    }

    pub fn strs(&self, name: &str) -> Vec<String> {
        self.get_all(name)
            .filter_map(|v| match v {
                PValue::Str(s) | PValue::Ident(s) => Some(s.clone()),
                _ => None,
            })
            .collect()
    }

    pub fn nums(&self, name: &str) -> Vec<f64> {
        self.get_all(name)
            .filter_map(|v| match v {
                PValue::Num(n) => Some(*n),
                _ => None,
            })
            .collect()
    }

    pub fn msgs<'a>(&'a self, name: &'a str) -> impl Iterator<Item = &'a PMessage> {
        self.get_all(name).filter_map(|v| match v {
            PValue::Msg(m) => Some(m),
            _ => None,
        })
    }

    pub fn push(&mut self, name: &str, value: PValue) -> &mut Self {
        self.fields.push((name.to_string(), value));
        self
    }
}

/// Parse a token stream into a message (the whole file is one message body).
pub fn parse(tokens: &[Token]) -> Result<PMessage, String> {
    let mut pos = 0;
    let msg = parse_body(tokens, &mut pos, true)?;
    if pos != tokens.len() {
        return Err(format!(
            "line {}: unexpected trailing tokens",
            tokens[pos].line
        ));
    }
    Ok(msg)
}

fn parse_body(tokens: &[Token], pos: &mut usize, top: bool) -> Result<PMessage, String> {
    let mut msg = PMessage::default();
    loop {
        match tokens.get(*pos) {
            None => {
                if top {
                    return Ok(msg);
                }
                return Err("unexpected end of input (unclosed '{')".into());
            }
            Some(Token { tok: Tok::RBrace, line }) => {
                if top {
                    return Err(format!("line {line}: unmatched '}}'"));
                }
                *pos += 1;
                return Ok(msg);
            }
            Some(Token { tok: Tok::Ident(name), line }) => {
                let name = name.clone();
                let line = *line;
                *pos += 1;
                match tokens.get(*pos) {
                    Some(Token { tok: Tok::Colon, .. }) => {
                        *pos += 1;
                        let val = match tokens.get(*pos) {
                            Some(Token { tok: Tok::Str(s), .. }) => PValue::Str(s.clone()),
                            Some(Token { tok: Tok::Num(n), .. }) => PValue::Num(*n),
                            Some(Token { tok: Tok::Ident(s), .. }) => PValue::Ident(s.clone()),
                            Some(Token { tok: Tok::LBrace, .. }) => {
                                // `field: { ... }` is also legal text format.
                                *pos += 1;
                                let inner = parse_body(tokens, pos, false)?;
                                msg.push(&name, PValue::Msg(inner));
                                continue;
                            }
                            other => {
                                return Err(format!(
                                    "line {line}: expected value after '{name}:', found {other:?}"
                                ))
                            }
                        };
                        *pos += 1;
                        msg.push(&name, val);
                    }
                    Some(Token { tok: Tok::LBrace, .. }) => {
                        *pos += 1;
                        let inner = parse_body(tokens, pos, false)?;
                        msg.push(&name, PValue::Msg(inner));
                    }
                    other => {
                        return Err(format!(
                            "line {line}: expected ':' or '{{' after '{name}', found {other:?}"
                        ))
                    }
                }
            }
            Some(Token { tok, line }) => {
                return Err(format!("line {line}: unexpected token {tok:?}"))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    fn parse_str(s: &str) -> PMessage {
        parse(&lex(s).unwrap()).unwrap()
    }

    #[test]
    fn scalar_fields() {
        let m = parse_str("name: \"LeNet\" base_lr: 0.01 solver_mode: GPU debug: true");
        assert_eq!(m.get_str("name"), Some("LeNet"));
        assert_eq!(m.get_num("base_lr"), Some(0.01));
        assert_eq!(m.get_str("solver_mode"), Some("GPU"));
        assert_eq!(m.get_bool("debug"), Some(true));
    }

    #[test]
    fn repeated_and_nested() {
        let m = parse_str(
            "layer { name: \"a\" top: \"a\" }\nlayer { name: \"b\" bottom: \"a\" bottom: \"a2\" }",
        );
        let layers: Vec<&PMessage> = m.msgs("layer").collect();
        assert_eq!(layers.len(), 2);
        assert_eq!(layers[1].strs("bottom"), vec!["a", "a2"]);
    }

    #[test]
    fn colon_brace_form() {
        let m = parse_str("param: { lr_mult: 2 }");
        assert_eq!(m.get_msg("param").unwrap().get_num("lr_mult"), Some(2.0));
    }

    #[test]
    fn deep_nesting() {
        let m = parse_str("a { b { c { d: 4 } } }");
        let d = m
            .get_msg("a")
            .and_then(|x| x.get_msg("b"))
            .and_then(|x| x.get_msg("c"))
            .and_then(|x| x.get_num("d"));
        assert_eq!(d, Some(4.0));
    }

    #[test]
    fn errors_on_malformed() {
        assert!(parse(&lex("a: ").unwrap()).is_err());
        assert!(parse(&lex("}").unwrap()).is_err());
        assert!(parse(&lex("a {").unwrap()).is_err());
    }
}
