"""L1 Pallas kernels: tiled GEMM + GEMV.

The paper's two "significant kernels" (Table 3) use NDRange with 2-D /
1-D *local-memory* tiles on the FPGA. The TPU analogue (DESIGN.md §8):

  FPGA DDR -> M20K local tile      ==>   HBM -> VMEM via BlockSpec
  TMxTN work-group MAC lanes       ==>   MXU tile matmul per grid step
  K-loop inside the kernel         ==>   third grid axis, @pl.when
                                         zero-init / accumulate on the
                                         revolving output tile

Kernels are lowered with ``interpret=True`` so the HLO runs on the PJRT
CPU backend (real-TPU lowering emits Mosaic custom-calls the CPU plugin
cannot execute). Tile sizes are chosen to fit comfortably in VMEM
(<= ~1.5 MB of operand tiles per step, 16 MB/core budget) and to keep the
interpret-mode grid small.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def pick_tiles(m: int, n: int, k: int):
    """Tile selection: MXU-shaped 128-lane output tiles, K staged through
    VMEM in 512-element panels. Shapes smaller than a tile collapse to one
    grid step (VMEM footprint: TM*TK + TK*TN + TM*TN floats)."""
    tm = min(_ceil_to(m, 8), 128)
    tn = min(_ceil_to(n, 128), 512)
    tk = min(_ceil_to(k, 128), 512)
    return tm, tn, tk


def vmem_floats(m: int, n: int, k: int) -> int:
    """VMEM working-set estimate (floats) for the chosen tiles — used by
    the §Perf roofline notes."""
    tm, tn, tk = pick_tiles(m, n, k)
    return tm * tk + tk * tn + tm * tn


def _gemm_kernel(a_ref, b_ref, o_ref):
    """Grid = (M/TM, N/TN, K/TK); the output tile revolves over the K axis
    (paper: C stays in registers while A/B tiles stream through local
    memory)."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def gemm_nn(a, b):
    """a: (m, k) f32, b: (k, n) f32 -> (m, n). Pads to tile multiples
    (zero padding is exact for matmul) and slices back."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    tm, tn, tk = pick_tiles(m, n, k)
    mp, np_, kp = _ceil_to(m, tm), _ceil_to(n, tn), _ceil_to(k, tk)
    a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    out = pl.pallas_call(
        _gemm_kernel,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid=(mp // tm, np_ // tn, kp // tk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        interpret=True,
    )(a, b)
    return out[:m, :n]


def gemm(a, b, ta=False, tb=False, c=None):
    """caffe_cpu_gemm equivalent: op(A)(m,k) x op(B)(k,n) [+ C].

    The transposed operands reach the same L1 NN kernel through an XLA
    transpose (fused into the operand copy), matching how the paper routes
    every convolution variant through the one optimized gemm kernel.
    """
    if ta:
        a = a.T
    if tb:
        b = b.T
    out = gemm_nn(a, b)
    if c is not None:
        out = out + c
    return out


def _gemv_kernel(a_ref, x_ref, o_ref):
    """1-D tile: TM rows of A stream through VMEM, x is resident
    (paper: gemv uses a 1-D local buffer + SIMD reduction)."""
    o_ref[...] = jnp.dot(a_ref[...], x_ref[...], preferred_element_type=jnp.float32)


def gemv_n(a, x):
    """a: (m, n), x: (n,) -> (m,)."""
    m, n = a.shape
    tm = min(_ceil_to(m, 8), 256)
    mp = _ceil_to(m, tm)
    a = jnp.pad(a, ((0, mp - m), (0, 0)))
    out = pl.pallas_call(
        _gemv_kernel,
        out_shape=jax.ShapeDtypeStruct((mp,), jnp.float32),
        grid=(mp // tm,),
        in_specs=[
            pl.BlockSpec((tm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tm,), lambda i: (i,)),
        interpret=True,
    )(a, x)
    return out[:m]


def gemv(a, x, trans=False, y=None):
    """caffe_cpu_gemv: op(A) x [+ y]; A is (m, n) row-major."""
    out = gemv_n(a.T if trans else a, x)
    if y is not None:
        out = out + y
    return out


@functools.lru_cache(maxsize=None)
def _noop():  # keep functools import purposeful under linting
    return None
