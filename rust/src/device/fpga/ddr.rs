//! Device-DDR capacity accounting for the simulated board.
//!
//! The S10 dev kit has 2 GB of DDR (paper Table 4) — small enough that
//! VGG-16/19 *training* does not fit (paper §4.4). This tracker enforces
//! that: allocations beyond capacity fail, and the VGG-training bench
//! reproduces the paper's "cannot be performed" result instead of
//! silently using host RAM.

use std::collections::BTreeMap;

#[derive(Debug)]
pub struct DdrTracker {
    capacity: u64,
    used: u64,
    peak: u64,
    /// bytes per live allocation id
    live: BTreeMap<usize, u64>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OutOfDeviceMemory {
    pub requested: u64,
    pub used: u64,
    pub capacity: u64,
}

impl std::fmt::Display for OutOfDeviceMemory {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "FPGA DDR exhausted: requested {} B with {}/{} B in use",
            self.requested, self.used, self.capacity
        )
    }
}

impl std::error::Error for OutOfDeviceMemory {}

impl DdrTracker {
    pub fn new(capacity: u64) -> DdrTracker {
        DdrTracker { capacity, used: 0, peak: 0, live: BTreeMap::new() }
    }

    pub fn alloc(&mut self, id: usize, bytes: u64) -> Result<(), OutOfDeviceMemory> {
        if self.used + bytes > self.capacity {
            return Err(OutOfDeviceMemory {
                requested: bytes,
                used: self.used,
                capacity: self.capacity,
            });
        }
        let prev = self.live.insert(id, bytes);
        assert!(prev.is_none(), "ddr: id {id} already live");
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        Ok(())
    }

    pub fn free(&mut self, id: usize) {
        let bytes = self.live.remove(&id).expect("ddr: free of unknown id");
        self.used -= bytes;
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn peak(&self) -> u64 {
        self.peak
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_used_and_peak() {
        let mut d = DdrTracker::new(100);
        d.alloc(1, 40).unwrap();
        d.alloc(2, 50).unwrap();
        assert_eq!(d.used(), 90);
        d.free(1);
        assert_eq!(d.used(), 50);
        assert_eq!(d.peak(), 90);
    }

    #[test]
    fn rejects_over_capacity() {
        let mut d = DdrTracker::new(100);
        d.alloc(1, 80).unwrap();
        let err = d.alloc(2, 30).unwrap_err();
        assert_eq!(err.requested, 30);
        assert_eq!(err.used, 80);
        // failed alloc must not leak accounting
        assert_eq!(d.used(), 80);
        d.free(1);
        d.alloc(2, 100).unwrap();
    }

    #[test]
    #[should_panic(expected = "already live")]
    fn duplicate_id_panics() {
        let mut d = DdrTracker::new(100);
        d.alloc(1, 10).unwrap();
        let _ = d.alloc(1, 10);
    }
}
