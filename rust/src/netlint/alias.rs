//! Pass 3: in-place aliasing safety.
//!
//! An in-place layer lists the same blob as bottom and top, overwriting
//! its storage during forward. That is only well-defined for elementwise
//! kinds whose runtime kernels tolerate it (`ReLU`, `Dropout`) — any
//! other kind in-place is `NL0201`. Separately, a *pure* consumer that
//! reads the blob before an in-place layer overwrites it, while another
//! consumer reads it after, straddles the overwrite (`NL0202`): the net
//! only works because split insertion materializes a copy, which costs a
//! DDR round-trip and usually signals a miswired prototxt.

use super::LintDiagnostic;
use crate::proto::LayerParameter;

/// Layer kinds whose forward kernels are safe to run in-place.
const IN_PLACE_SAFE: &[&str] = &["ReLU", "Dropout"];

pub fn check(layers: &[LayerParameter], diags: &mut Vec<LintDiagnostic>) {
    for (i, lp) in layers.iter().enumerate() {
        for t in &lp.tops {
            if !lp.bottoms.contains(t) {
                continue;
            }
            if !IN_PLACE_SAFE.contains(&lp.kind.as_str()) {
                diags.push(
                    LintDiagnostic::error(
                        "NL0201",
                        Some(lp.name.as_str()),
                        format!(
                            "{} computes blob '{t}' in-place, but its kernel reads the \
                             full bottom while writing the top",
                            lp.kind
                        ),
                    )
                    .with_help(format!(
                        "only {} support in-place; give the top a fresh name",
                        IN_PLACE_SAFE.join("/")
                    )),
                );
                continue;
            }
            // Straddle: a pure reader strictly before this overwrite plus
            // any reader after it. Prior *in-place* writers of the same
            // blob are a chain (relu → dropout), not a straddle.
            let pure_before = layers[..i]
                .iter()
                .any(|l| l.bottoms.contains(t) && !l.tops.contains(t));
            let reader_after = layers[i + 1..].iter().any(|l| l.bottoms.contains(t));
            if pure_before && reader_after {
                diags.push(
                    LintDiagnostic::warning(
                        "NL0202",
                        Some(lp.name.as_str()),
                        format!(
                            "blob '{t}' is read before this in-place layer overwrites it \
                             and again after; consumers see different values"
                        ),
                    )
                    .with_help(
                        "split insertion keeps this correct but forces an extra copy; \
                         rename the in-place top if both values are really needed",
                    ),
                );
            }
        }
    }
}
