//! The `FEPLAN1` on-disk container: one recorded execution plan per
//! (net, serving bucket), serialized through [`crate::util::binio`] like
//! the `FEWSNAP1` weight snapshot — little-endian, length-prefixed
//! strings, and every length bounded by the file size *before* any
//! allocation, so corrupt or truncated containers fail with a typed
//! [`AotError`] instead of an OOM or a panic.
//!
//! Field order is fixed by this module (all collections are emitted from
//! sorted `Vec`s built off `BTreeMap` walks), so the same inputs always
//! produce byte-identical files — the property the CI `repro` leg
//! asserts over the whole artifact tree.

use super::{AotError, PlanArtifact, PlanEnvelope};
use crate::util::binio::{get_str, get_u32, get_u64, put_str, put_u32, put_u64};
use std::io::Write;

/// 8-byte container magic.
pub const MAGIC: &[u8; 8] = b"FEPLAN1\0";

/// Bumped whenever the container layout changes; readers refuse other
/// versions (distinct from [`super::CODE_VERSION`], which keys the
/// *content* of the plans).
pub const FORMAT_VERSION: u32 = 1;

/// Shapes are NCHW-ish; anything past this is a corrupt dim count.
const MAX_DIMS: usize = 16;

/// Serialize `art` in the fixed field order. Infallible layout — any
/// error is the writer's I/O error.
pub fn write_artifact(w: &mut impl Write, art: &PlanArtifact) -> std::io::Result<()> {
    w.write_all(MAGIC)?;
    put_u32(w, FORMAT_VERSION)?;
    put_str(w, &art.key)?;
    let env = &art.envelope;
    put_str(w, &env.net)?;
    put_str(w, &env.device)?;
    put_u32(w, env.code_version)?;
    put_u64(w, env.bucket as u64)?;
    put_u64(w, env.sample_len as u64)?;
    put_u64(w, env.ddr_peak_bytes)?;
    put_u64(w, env.ddr_capacity_bytes)?;
    put_u32(w, env.blob_shapes.len() as u32)?;
    for (name, dims) in &env.blob_shapes {
        put_str(w, name)?;
        put_u32(w, dims.len() as u32)?;
        for &d in dims {
            put_u64(w, d as u64)?;
        }
    }
    put_u32(w, env.weight_keys.len() as u32)?;
    for ((owner, slot), len) in env.weight_keys.iter().zip(&env.weight_lens) {
        put_str(w, owner)?;
        put_u32(w, *slot as u32)?;
        put_u64(w, *len as u64)?;
    }
    put_u32(w, art.plans.len() as u32)?;
    for (key, spec) in &art.plans {
        put_str(w, key)?;
        put_str(w, spec)?;
    }
    Ok(())
}

/// The container bytes for `art` (what `save` writes and the manifest
/// hashes).
pub fn artifact_bytes(art: &PlanArtifact) -> Vec<u8> {
    let mut buf = Vec::new();
    write_artifact(&mut buf, art).expect("Vec<u8> writes are infallible");
    buf
}

/// Parse a container from its full byte image. `path` labels errors.
pub fn read_artifact(bytes: &[u8], path: &str) -> Result<PlanArtifact, AotError> {
    let file_len = bytes.len();
    let corrupt = |detail: String| AotError::Corrupt { path: path.to_string(), detail };
    let mut r = bytes;

    let mut magic = [0u8; 8];
    std::io::Read::read_exact(&mut r, &mut magic)
        .map_err(|_| corrupt("shorter than the 8-byte magic".to_string()))?;
    if &magic != MAGIC {
        return Err(corrupt(format!("bad magic {magic:02x?} (want FEPLAN1)")));
    }
    let version = get_u32(&mut r).map_err(|e| corrupt(format!("format version: {e}")))?;
    if version != FORMAT_VERSION {
        return Err(corrupt(format!(
            "container format v{version} (this build reads v{FORMAT_VERSION})"
        )));
    }

    let key = get_str(&mut r, file_len).map_err(|e| corrupt(format!("content key: {e}")))?;
    let net = get_str(&mut r, file_len).map_err(|e| corrupt(format!("net name: {e}")))?;
    let device = get_str(&mut r, file_len).map_err(|e| corrupt(format!("device config: {e}")))?;
    let code_version = get_u32(&mut r).map_err(|e| corrupt(format!("code version: {e}")))?;
    let bucket = get_u64(&mut r).map_err(|e| corrupt(format!("bucket: {e}")))? as usize;
    let sample_len = get_u64(&mut r).map_err(|e| corrupt(format!("sample_len: {e}")))? as usize;
    let ddr_peak_bytes =
        get_u64(&mut r).map_err(|e| corrupt(format!("ddr_peak_bytes: {e}")))?;
    let ddr_capacity_bytes =
        get_u64(&mut r).map_err(|e| corrupt(format!("ddr_capacity_bytes: {e}")))?;

    // Each shape record is ≥ 4+4 bytes, each weight ≥ 4+4+8, each plan
    // ≥ 4+4: counts beyond that are corrupt length prefixes, refused
    // before any allocation sized by them.
    let n_shapes = get_u32(&mut r).map_err(|e| corrupt(format!("shape count: {e}")))? as usize;
    if n_shapes > file_len / 8 {
        return Err(corrupt(format!("implausible shape count {n_shapes} for {file_len} bytes")));
    }
    let mut blob_shapes = Vec::with_capacity(n_shapes);
    for i in 0..n_shapes {
        let name =
            get_str(&mut r, file_len).map_err(|e| corrupt(format!("shape {i} name: {e}")))?;
        let ndim = get_u32(&mut r).map_err(|e| corrupt(format!("shape {i} ndim: {e}")))? as usize;
        if ndim > MAX_DIMS {
            return Err(corrupt(format!("shape '{name}' claims {ndim} dims (max {MAX_DIMS})")));
        }
        let mut dims = Vec::with_capacity(ndim);
        for d in 0..ndim {
            dims.push(
                get_u64(&mut r).map_err(|e| corrupt(format!("shape '{name}' dim {d}: {e}")))?
                    as usize,
            );
        }
        blob_shapes.push((name, dims));
    }

    let n_weights =
        get_u32(&mut r).map_err(|e| corrupt(format!("weight count: {e}")))? as usize;
    if n_weights > file_len / 16 {
        return Err(corrupt(format!(
            "implausible weight count {n_weights} for {file_len} bytes"
        )));
    }
    let mut weight_keys = Vec::with_capacity(n_weights);
    let mut weight_lens = Vec::with_capacity(n_weights);
    for i in 0..n_weights {
        let owner =
            get_str(&mut r, file_len).map_err(|e| corrupt(format!("weight {i} owner: {e}")))?;
        let slot =
            get_u32(&mut r).map_err(|e| corrupt(format!("weight {i} slot: {e}")))? as usize;
        let len = get_u64(&mut r).map_err(|e| corrupt(format!("weight {i} len: {e}")))? as usize;
        weight_keys.push((owner, slot));
        weight_lens.push(len);
    }

    let n_plans = get_u32(&mut r).map_err(|e| corrupt(format!("plan count: {e}")))? as usize;
    if n_plans > file_len / 8 {
        return Err(corrupt(format!("implausible plan count {n_plans} for {file_len} bytes")));
    }
    let mut plans = Vec::with_capacity(n_plans);
    for i in 0..n_plans {
        let k = get_str(&mut r, file_len).map_err(|e| corrupt(format!("plan {i} key: {e}")))?;
        let spec =
            get_str(&mut r, file_len).map_err(|e| corrupt(format!("plan '{k}' spec: {e}")))?;
        plans.push((k, spec));
    }

    if !r.is_empty() {
        return Err(corrupt(format!("{} trailing byte(s) after the last plan", r.len())));
    }

    Ok(PlanArtifact {
        key,
        envelope: PlanEnvelope {
            net,
            device,
            code_version,
            bucket,
            sample_len,
            ddr_peak_bytes,
            ddr_capacity_bytes,
            blob_shapes,
            weight_keys,
            weight_lens,
        },
        plans,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_artifact() -> PlanArtifact {
        PlanArtifact {
            key: "ab".repeat(32),
            envelope: PlanEnvelope {
                net: "LeNet_deploy".to_string(),
                device: "board:ddr=2147483648".to_string(),
                code_version: 1,
                bucket: 4,
                sample_len: 784,
                ddr_peak_bytes: 123_456,
                ddr_capacity_bytes: 2_147_483_648,
                blob_shapes: vec![
                    ("conv1".to_string(), vec![4, 20, 24, 24]),
                    ("data".to_string(), vec![4, 1, 28, 28]),
                ],
                weight_keys: vec![("conv1".to_string(), 0), ("conv1".to_string(), 1)],
                weight_lens: vec![500, 20],
            },
            plans: vec![
                ("gemm_nn_20x25x576".to_string(), "{\"op\": \"gemm_nn\"}".to_string()),
                ("relu_f_512".to_string(), "{\"op\": \"relu_f\"}".to_string()),
            ],
        }
    }

    #[test]
    fn round_trips_and_is_byte_deterministic() {
        let art = sample_artifact();
        let a = artifact_bytes(&art);
        let b = artifact_bytes(&art);
        assert_eq!(a, b, "same artifact → same bytes");
        let back = read_artifact(&a, "test.feplan").unwrap();
        assert_eq!(back.key, art.key);
        assert_eq!(back.envelope, art.envelope);
        assert_eq!(back.plans, art.plans);
    }

    #[test]
    fn refuses_bad_magic_and_version() {
        let mut bytes = artifact_bytes(&sample_artifact());
        bytes[0] = b'X';
        let err = read_artifact(&bytes, "p").unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err}");
        let mut bytes = artifact_bytes(&sample_artifact());
        bytes[8] = 99; // format version LE byte 0
        let err = read_artifact(&bytes, "p").unwrap_err();
        assert!(err.to_string().contains("format v99"), "{err}");
    }

    #[test]
    fn refuses_truncation_at_every_length() {
        let bytes = artifact_bytes(&sample_artifact());
        // Every strict prefix must fail typed — never panic, never parse.
        for cut in 0..bytes.len() {
            let err = read_artifact(&bytes[..cut], "p").unwrap_err();
            assert!(
                matches!(err, AotError::Corrupt { .. }),
                "cut at {cut} gave {err}"
            );
        }
    }

    #[test]
    fn refuses_trailing_garbage() {
        let mut bytes = artifact_bytes(&sample_artifact());
        bytes.push(0);
        let err = read_artifact(&bytes, "p").unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err}");
    }

    #[test]
    fn bounds_counts_before_allocating() {
        let art = sample_artifact();
        let bytes = artifact_bytes(&art);
        // Find the shape-count u32 and replace it with a huge value: the
        // reader must refuse on plausibility, not try to allocate.
        let key_end = 8 + 4 + 4 + art.key.len();
        let net_end = key_end + 4 + art.envelope.net.len();
        let dev_end = net_end + 4 + art.envelope.device.len();
        let shape_count_at = dev_end + 4 + 8 * 4;
        let mut evil = bytes.clone();
        evil[shape_count_at..shape_count_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_artifact(&evil, "p").unwrap_err();
        assert!(err.to_string().contains("implausible shape count"), "{err}");
    }
}
