//! Integration: the observability layer end to end.
//!
//! * `trace_sample: 1` on an FPGA-sim engine → every batch lands in the
//!   trace ring with the full lifecycle on one timeline: queue wait,
//!   host phases, per-layer forward spans and the device's rebased
//!   pcie / fpga-kernel lanes, in causal order;
//! * per-layer aggregates accumulate wall *and* simulated time;
//! * over HTTP: `GET /metrics?format=prometheus` renders the metric
//!   families, `GET /admin/trace` returns chrome-trace JSON with ≥1
//!   sampled batch, and `?clear=1` empties the ring.

use fecaffe::obs::{LANE_LAYER, LANE_QUEUE};
use fecaffe::serve::{
    http_request, DeviceKind, Engine, EngineConfig, HttpConfig, HttpServer, ModelRouter,
};
use fecaffe::util::json::Json;
use fecaffe::zoo;
use std::sync::Arc;
use std::time::Duration;

fn traced_fpga_engine() -> Engine {
    let param = zoo::by_name("lenet", 1).unwrap();
    Engine::new(
        &param,
        EngineConfig {
            workers: 1,
            max_batch: 4,
            max_linger: Duration::from_micros(500),
            queue_capacity: 64,
            device: DeviceKind::FpgaSim,
            intra_op_threads: 1,
            trace_sample: 1,
            ..EngineConfig::default()
        },
    )
    .unwrap()
}

fn run_requests(engine: &Engine, n: usize) {
    let handles: Vec<_> = (0..n)
        .map(|_| engine.submit(vec![0.5f32; engine.sample_len()]).unwrap())
        .collect();
    for h in handles {
        h.wait().unwrap();
    }
}

#[test]
fn sampled_batch_trace_covers_the_full_lifecycle_in_order() {
    let engine = traced_fpga_engine();
    run_requests(&engine, 6);
    engine.shutdown();

    let traces = engine.obs().traces.dump();
    assert!(!traces.is_empty(), "trace_sample=1 must capture batches");
    let t = &traces[0];
    assert!(t.filled >= 1 && t.rows >= t.filled, "{}/{}", t.filled, t.rows);

    let find = |name: &str| t.spans.iter().find(|s| s.name == name);
    let queue_wait = find("queue-wait").expect("queue-wait span");
    assert_eq!(queue_wait.lane, LANE_QUEUE);
    // The trace origin is the oldest request's submit time, so the
    // queue wait is the first thing on the timeline.
    assert_eq!(queue_wait.start_ns, 0);
    let forward = find("forward").expect("host forward span");
    let gather = find("gather").expect("host gather span");
    let scatter = find("scatter").expect("host scatter span");

    let layers: Vec<_> = t.spans.iter().filter(|s| s.lane == LANE_LAYER).collect();
    assert!(!layers.is_empty(), "per-layer spans missing");
    for l in &layers {
        // Layer spans nest inside the forward envelope.
        assert!(l.start_ns >= forward.start_ns, "{} before forward", l.name);
        assert!(
            l.start_ns + l.dur_ns <= forward.start_ns + forward.dur_ns + 1_000_000,
            "{} ends long after forward",
            l.name
        );
    }
    // Causal order across phases: gather → forward → scatter.
    assert!(gather.start_ns <= forward.start_ns);
    assert!(forward.start_ns <= scatter.start_ns);

    // The FPGA-sim device contributed rebased kernel spans that sit
    // after the batch was picked up (never before the queue wait ends).
    let kernels: Vec<_> = t.spans.iter().filter(|s| s.lane == "fpga-kernel").collect();
    assert!(!kernels.is_empty(), "fpga-kernel lane missing");
    for k in &kernels {
        assert!(k.start_ns >= queue_wait.dur_ns, "kernel span inside queue wait");
    }

    // Per-layer aggregates saw the same batches, with simulated time.
    let layer_stats = engine.obs().layers.snapshot();
    assert!(!layer_stats.is_empty());
    assert!(layer_stats.iter().any(|(_, a)| a.sim_ns > 0), "no sim time recorded");
    assert!(layer_stats.iter().all(|(_, a)| a.batches > 0));
}

#[test]
fn trace_ring_clear_empties_it() {
    let engine = traced_fpga_engine();
    run_requests(&engine, 2);
    engine.shutdown();
    assert!(!engine.obs().traces.dump().is_empty());
    engine.obs().traces.clear();
    assert!(engine.obs().traces.dump().is_empty());
}

#[test]
fn http_surface_exposes_prometheus_and_chrome_traces() {
    let router = Arc::new(
        ModelRouter::from_engines(vec![("lenet".to_string(), traced_fpga_engine())]).unwrap(),
    );
    let sample_len = router.engine("lenet").unwrap().sample_len();
    let server = HttpServer::bind("127.0.0.1:0", router, HttpConfig::default()).unwrap();
    let addr = server.local_addr().to_string();

    // Drive a couple of predicts through the full HTTP path.
    let body = fecaffe::serve::http::predict_body(&[vec![0.25f32; sample_len]]);
    for _ in 0..2 {
        let (status, _) =
            http_request(&addr, "POST", "/v1/models/lenet:predict", body.as_bytes()).unwrap();
        assert_eq!(status, 200);
    }

    // Prometheus exposition: families rendered once, with model labels.
    let (status, text) =
        http_request(&addr, "GET", "/metrics?format=prometheus", b"").unwrap();
    assert_eq!(status, 200);
    let text = String::from_utf8(text).unwrap();
    for family in [
        "# TYPE fecaffe_requests_completed_total counter",
        "# TYPE fecaffe_request_latency_seconds histogram",
        "# TYPE fecaffe_queue_depth gauge",
        "fecaffe_requests_completed_total{model=\"lenet\",precision=\"fp32\"}",
        "fecaffe_request_latency_seconds_bucket{model=\"lenet\",precision=\"fp32\",le=\"+Inf\"}",
    ] {
        assert!(text.contains(family), "missing: {family}\n{text}");
    }
    // Per-layer counters ride along once batches have executed.
    assert!(text.contains("fecaffe_layer_sim_seconds_total"), "{text}");

    // The default JSON form still works alongside.
    let (status, json) = http_request(&addr, "GET", "/metrics", b"").unwrap();
    assert_eq!(status, 200);
    Json::parse(std::str::from_utf8(&json).unwrap()).unwrap();

    // /admin/trace: chrome-trace JSON with at least one sampled batch.
    // (The worker commits a batch's trace just after fulfilling its
    // responses; give that tail a moment so the clear below is final.)
    std::thread::sleep(Duration::from_millis(300));
    let (status, trace) = http_request(&addr, "GET", "/admin/trace?clear=1", b"").unwrap();
    assert_eq!(status, 200);
    let doc = Json::parse(std::str::from_utf8(&trace).unwrap()).unwrap();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "no trace events");
    let span_names: Vec<&str> = events
        .iter()
        .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
        .map(|e| e.get("name").unwrap().as_str().unwrap())
        .collect();
    assert!(span_names.contains(&"queue-wait"), "{span_names:?}");
    assert!(
        events.iter().any(|e| e.get("cat").and_then(|c| c.as_str()) == Some("layer")),
        "no layer-lane events"
    );
    // Process groups are labelled per batch.
    assert!(
        events.iter().any(|e| e.get("name").unwrap().as_str() == Some("process_name")),
        "batch process groups missing"
    );

    // ?clear=1 above emptied the ring: with no new batches since, the
    // next dump has no events.
    let (status, trace) = http_request(&addr, "GET", "/admin/trace", b"").unwrap();
    assert_eq!(status, 200);
    let doc = Json::parse(std::str::from_utf8(&trace).unwrap()).unwrap();
    assert!(doc.get("traceEvents").unwrap().as_arr().unwrap().is_empty());

    server.shutdown();
}
