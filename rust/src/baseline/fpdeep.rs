//! FPDeep cluster model (Table 4's second comparator).
//!
//! FPDeep pipelines *all layers* of the network across a 15-FPGA chain
//! (VC709 / V7-690T, 2880 DSPs each), keeps every weight/activation in
//! BRAM, and computes in fixed-point 16 — so its throughput is DSP-bound,
//! not DDR-bound. We model the cluster as a dense systolic farm:
//! `imgs/s = DSPs_total × fmax × util / MACs_per_image` (2 MACs per DSP
//! per cycle at fixp16).

pub struct FpdeepCluster {
    pub boards: usize,
    pub dsps_per_board: u64,
    pub fmax_hz: f64,
    pub macs_per_dsp_cycle: f64,
    pub utilization: f64,
}

impl Default for FpdeepCluster {
    fn default() -> Self {
        FpdeepCluster {
            boards: 15,
            dsps_per_board: 2880,
            fmax_hz: 150.0e6,
            macs_per_dsp_cycle: 2.0, // fixp16 packs two MACs per DSP48
            utilization: 0.55,
        }
    }
}

impl FpdeepCluster {
    pub fn total_dsps(&self) -> u64 {
        self.boards as u64 * self.dsps_per_board
    }

    /// Images/second on a network of `macs_per_image` (fwd+bwd ≈ 3× fwd).
    pub fn train_images_per_s(&self, fwd_macs_per_image: f64) -> f64 {
        let macs_s =
            self.total_dsps() as f64 * self.fmax_hz * self.macs_per_dsp_cycle * self.utilization;
        macs_s / (3.0 * fwd_macs_per_image)
    }

    /// Hours to train one ImageNet epoch.
    pub fn epoch_hours(&self, fwd_macs_per_image: f64, images: usize) -> f64 {
        images as f64 / self.train_images_per_s(fwd_macs_per_image) / 3600.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alexnet_epoch_near_published() {
        // AlexNet ≈ 0.72 GMACs/image forward; published epoch: 0.17 h.
        let c = FpdeepCluster::default();
        let h = c.epoch_hours(0.72e9, 1_281_167);
        assert!((0.05..0.5).contains(&h), "epoch {h} h vs published 0.17 h");
    }

    #[test]
    fn dsp_total_matches_paper() {
        assert_eq!(FpdeepCluster::default().total_dsps(), 43_200);
    }
}
