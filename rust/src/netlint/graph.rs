//! Pass 1: graph hygiene.
//!
//! Walks the phase's layer list in declaration order (the same order
//! [`crate::net::Net::from_param`] builds in — Caffe nets are
//! topologically sorted by construction, so any bottom that is not yet
//! available is a wiring bug, not a scheduling choice):
//!
//! * `NL0001` — bottom blob never produced anywhere (dangling);
//! * `NL0002` — bottom produced only *later* (forward reference — the
//!   declaration-order form a cycle takes in a prototxt);
//! * `NL0003` — non-in-place redefinition of an existing top (two
//!   producers for one blob name; `Net::from_param` would silently
//!   shadow the first);
//! * `NL0004` — layer unreachable from any loss/accuracy output (dead
//!   weight that still costs DDR and schedule slots);
//! * `NL0005` — bottom produced only by layers of the *other* phase
//!   (phase-inconsistent wiring).

use super::LintDiagnostic;
use crate::proto::{NetParameter, Phase};
use std::collections::HashSet;

pub fn check(param: &NetParameter, phase: Phase, diags: &mut Vec<LintDiagnostic>) {
    let layers = param.layers_for_phase(phase);
    let other = match phase {
        Phase::Train => Phase::Test,
        Phase::Test => Phase::Train,
    };

    // Every top any in-phase layer produces (for forward-reference vs
    // dangling), and tops exclusive to the other phase (for NL0005).
    let mut phase_tops: HashSet<&str> = HashSet::new();
    for l in &layers {
        phase_tops.extend(l.tops.iter().map(String::as_str));
    }
    let mut other_tops: HashSet<&str> = HashSet::new();
    for l in param.layers_for_phase(other) {
        other_tops.extend(l.tops.iter().map(String::as_str));
    }

    let mut available: HashSet<&str> = param.inputs.iter().map(|(n, _)| n.as_str()).collect();
    let mut defined: HashSet<&str> = available.clone();

    for lp in &layers {
        for b in &lp.bottoms {
            if available.contains(b.as_str()) {
                continue;
            }
            if phase_tops.contains(b.as_str()) {
                diags.push(
                    LintDiagnostic::error(
                        "NL0002",
                        Some(lp.name.as_str()),
                        format!("bottom '{b}' is consumed before any layer produces it"),
                    )
                    .with_help(
                        "layers must be declared producer-first (a forward reference \
                         here means a cycle or a mis-ordered prototxt)",
                    ),
                );
            } else if other_tops.contains(b.as_str()) {
                diags.push(LintDiagnostic::error(
                    "NL0005",
                    Some(lp.name.as_str()),
                    format!(
                        "bottom '{b}' is only produced in the {} phase, but this layer \
                         runs in {}",
                        other.ident(),
                        phase.ident()
                    ),
                ));
            } else {
                diags.push(
                    LintDiagnostic::error(
                        "NL0001",
                        Some(lp.name.as_str()),
                        format!("bottom '{b}' is never produced by any layer or input"),
                    )
                    .with_help("add a producing layer, an `input:` declaration, or fix the name"),
                );
            }
        }
        let mut seen_here: HashSet<&str> = HashSet::new();
        for t in &lp.tops {
            let in_place = lp.bottoms.contains(t);
            if !seen_here.insert(t.as_str()) {
                diags.push(LintDiagnostic::error(
                    "NL0003",
                    Some(lp.name.as_str()),
                    format!("top '{t}' is listed twice by the same layer"),
                ));
            } else if !in_place && defined.contains(t.as_str()) {
                diags.push(
                    LintDiagnostic::error(
                        "NL0003",
                        Some(lp.name.as_str()),
                        format!("top '{t}' is already produced by an earlier layer"),
                    )
                    .with_help(
                        "two producers for one blob name shadow each other; rename the \
                         top (in-place layers must list the blob as bottom AND top)",
                    ),
                );
            }
            available.insert(t.as_str());
            defined.insert(t.as_str());
        }
    }

    // Dead layers: reverse reachability from loss/accuracy tops. Only
    // meaningful when the net has such sinks (deploy nets express their
    // output implicitly — any unconsumed top is a legitimate output).
    let mut roots: HashSet<&str> = HashSet::new();
    for l in &layers {
        let is_sink = l.kind == "SoftmaxWithLoss"
            || l.kind == "Accuracy"
            || l.loss_weight.iter().any(|&w| w != 0.0);
        if is_sink {
            roots.extend(l.tops.iter().map(String::as_str));
        }
    }
    if roots.is_empty() {
        return;
    }
    let mut needed: HashSet<&str> = roots;
    for lp in layers.iter().rev() {
        if lp.tops.iter().any(|t| needed.contains(t.as_str())) {
            needed.extend(lp.bottoms.iter().map(String::as_str));
        } else {
            diags.push(
                LintDiagnostic::warning(
                    "NL0004",
                    Some(lp.name.as_str()),
                    format!(
                        "layer is unreachable from any loss/accuracy output in the {} phase",
                        phase.ident()
                    ),
                )
                .with_help(
                    "dead layers still run and consume DDR; remove them or wire their \
                     tops into the graph",
                ),
            );
        }
    }
}
