//! Tiny declarative CLI argument parser (no clap offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments
//! and subcommands; generates usage text. Only what the `fecaffe` binary
//! and the bench harnesses need.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

/// Option/flag specification used for parsing + usage text.
#[derive(Debug, Clone)]
pub struct Spec {
    pub name: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

impl Spec {
    pub const fn opt(name: &'static str, default: Option<&'static str>, help: &'static str) -> Spec {
        Spec { name, takes_value: true, default, help }
    }
    pub const fn flag(name: &'static str, help: &'static str) -> Spec {
        Spec { name, takes_value: false, default: None, help }
    }
}

impl Args {
    /// Parse `argv` (without the program name) against the spec table.
    pub fn parse(argv: &[String], specs: &[Spec]) -> Result<Args, String> {
        let mut out = Args::default();
        // Seed defaults.
        for s in specs {
            if let Some(d) = s.default {
                out.options.insert(s.name.to_string(), d.to_string());
            }
        }
        let find = |name: &str| specs.iter().find(|s| s.name == name);
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = find(name).ok_or_else(|| format!("unknown option --{name}"))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} requires a value"))?
                        }
                    };
                    out.options.insert(name.to_string(), val);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("--{name} does not take a value"));
                    }
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, String> {
        self.get(name)
            .ok_or_else(|| format!("missing --{name}"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, String> {
        self.get(name)
            .ok_or_else(|| format!("missing --{name}"))?
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

pub fn usage(prog: &str, about: &str, specs: &[Spec]) -> String {
    let mut out = format!("{about}\n\nUsage: {prog} [options]\n\nOptions:\n");
    for s in specs {
        let lhs = if s.takes_value {
            format!("--{} <v>", s.name)
        } else {
            format!("--{}", s.name)
        };
        let def = s
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        out.push_str(&format!("  {lhs:<24} {}{def}\n", s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    const SPECS: &[Spec] = &[
        Spec::opt("model", Some("lenet"), "network name"),
        Spec::opt("iterations", Some("100"), "iteration count"),
        Spec::flag("verbose", "chatty output"),
    ];

    #[test]
    fn defaults_and_overrides() {
        let a = Args::parse(&sv(&["--iterations", "7"]), SPECS).unwrap();
        assert_eq!(a.get("model"), Some("lenet"));
        assert_eq!(a.get_usize("iterations").unwrap(), 7);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn equals_form_and_flags_and_positional() {
        let a = Args::parse(&sv(&["train", "--model=googlenet", "--verbose"]), SPECS).unwrap();
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("model"), Some("googlenet"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn errors() {
        assert!(Args::parse(&sv(&["--nope"]), SPECS).is_err());
        assert!(Args::parse(&sv(&["--model"]), SPECS).is_err());
        assert!(Args::parse(&sv(&["--verbose=1"]), SPECS).is_err());
    }

    #[test]
    fn usage_mentions_every_spec() {
        let u = usage("fecaffe", "about", SPECS);
        for s in SPECS {
            assert!(u.contains(s.name));
        }
    }
}
