//! Numeric backends for reduced-precision emulation.
//!
//! [`QuantBackend`] plugs into the device backend seam (the same one
//! `runtime::PjrtBackend` uses) and intercepts exactly the matmul
//! kernels — `GemmNN/NT/TN` and `Gemv` — executing them through the
//! emulated int8 path ([`super::gemm`]) or the fp16 storage-emulation
//! path. Everything else returns `Ok(false)` and falls through to
//! native fp32 math: the mixed-precision contract of an int8 FPGA
//! bitstream whose systolic array is quantized while the streaming
//! kernels stay in wider arithmetic.
//!
//! [`RangeObserver`] is the calibration-time twin: it *watches* the
//! same operands, recording per-kernel-shape min/max ranges, and always
//! declines execution so the fp32 forward proceeds untouched.

use super::calibrate::{quant_key, QuantSpec};
use super::f16::f16_round_slice;
use super::gemm::{minmax, qgemm, qgemv, quantize_slice, QuantParams, Trans};
use super::Precision;
use crate::device::fpga::NumericBackend;
use crate::device::native::Slab;
use crate::device::{Kernel, KernelCall};
use crate::math;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// The matmul kernels the quant path covers, with operand lengths.
enum Matmul {
    Gemm { ta: Trans, tb: Trans, m: usize, n: usize, k: usize, alpha: f32, beta: f32 },
    Gemv { trans: bool, m: usize, n: usize, alpha: f32, beta: f32 },
}

impl Matmul {
    fn of(kernel: &Kernel) -> Option<Matmul> {
        match *kernel {
            Kernel::GemmNN { m, n, k, alpha, beta } => {
                Some(Matmul::Gemm { ta: Trans::No, tb: Trans::No, m, n, k, alpha, beta })
            }
            Kernel::GemmNT { m, n, k, alpha, beta } => {
                Some(Matmul::Gemm { ta: Trans::No, tb: Trans::Yes, m, n, k, alpha, beta })
            }
            Kernel::GemmTN { m, n, k, alpha, beta } => {
                Some(Matmul::Gemm { ta: Trans::Yes, tb: Trans::No, m, n, k, alpha, beta })
            }
            Kernel::Gemv { trans, m, n, alpha, beta } => {
                Some(Matmul::Gemv { trans, m, n, alpha, beta })
            }
            _ => None,
        }
    }

    /// (A elements, B/x elements, C/y elements) regardless of storage
    /// orientation.
    fn lens(&self) -> (usize, usize, usize) {
        match *self {
            Matmul::Gemm { m, n, k, .. } => (m * k, k * n, m * n),
            Matmul::Gemv { trans, m, n, .. } => {
                let (xl, yl) = if trans { (m, n) } else { (n, m) };
                (m * n, xl, yl)
            }
        }
    }
}

/// Emulated reduced-precision matmul executor.
///
/// Int8: operands are quantized per call — using the calibrated ranges
/// from `spec` when present (static quantization), or the operands' own
/// observed range (dynamic) otherwise — then multiplied with exact i32
/// accumulation and requantized to f32. Fp16: operands are rounded
/// through the binary16 grid, accumulated in f32, and the output is
/// rounded back to the grid (half-precision storage, f32 accumulate).
/// Both paths are bit-identical at any intra-op thread count.
pub struct QuantBackend {
    precision: Precision,
    spec: Option<Arc<QuantSpec>>,
}

impl QuantBackend {
    pub fn new(precision: Precision, spec: Option<Arc<QuantSpec>>) -> QuantBackend {
        QuantBackend { precision, spec }
    }

    /// Per-operand quant params: calibrated ranges when the spec has an
    /// entry for this kernel shape, dynamic min/max otherwise.
    fn params(&self, kernel: &Kernel, a: &[f32], b: &[f32]) -> (QuantParams, QuantParams) {
        if let (Some(spec), Some(key)) = (self.spec.as_deref(), quant_key(kernel)) {
            if let Some([ra, rb]) = spec.ranges(&key) {
                return (
                    QuantParams::for_range(ra.0, ra.1),
                    QuantParams::for_range(rb.0, rb.1),
                );
            }
        }
        let (alo, ahi) = minmax(a);
        let (blo, bhi) = minmax(b);
        (QuantParams::for_range(alo, ahi), QuantParams::for_range(blo, bhi))
    }
}

impl NumericBackend for QuantBackend {
    fn execute(&mut self, slab: &mut Slab, call: &KernelCall) -> anyhow::Result<bool> {
        let Some(mm) = Matmul::of(&call.kernel) else {
            return Ok(false);
        };
        if self.precision == Precision::Fp32 {
            return Ok(false);
        }
        let (alen, blen, clen) = mm.lens();
        // Copy both inputs out first (quantized / grid-rounded), so a
        // later mutable borrow of the output cannot alias them even for
        // a pathological in-place call.
        let a_f32 = &slab.get(call.inputs[0])[call.in_offsets[0]..][..alen];
        match self.precision {
            Precision::Fp32 => unreachable!("handled above"),
            Precision::Int8 => {
                let (pa, pb) = {
                    let b_f32 = &slab.get(call.inputs[1])[call.in_offsets[1]..][..blen];
                    self.params(&call.kernel, a_f32, b_f32)
                };
                let aq = quantize_slice(a_f32, pa);
                let bq = quantize_slice(
                    &slab.get(call.inputs[1])[call.in_offsets[1]..][..blen],
                    pb,
                );
                let c = &mut slab.get_mut(call.outputs[0])[call.out_offsets[0]..][..clen];
                match mm {
                    Matmul::Gemm { ta, tb, m, n, k, alpha, beta } => {
                        qgemm(ta, tb, m, n, k, alpha, &aq, pa, &bq, pb, beta, c);
                    }
                    Matmul::Gemv { trans, m, n, alpha, beta } => {
                        let t = if trans { Trans::Yes } else { Trans::No };
                        qgemv(t, m, n, alpha, &aq, pa, &bq, pb, beta, c);
                    }
                }
            }
            Precision::Fp16 => {
                let mut a16 = a_f32.to_vec();
                f16_round_slice(&mut a16);
                let mut b16 =
                    slab.get(call.inputs[1])[call.in_offsets[1]..][..blen].to_vec();
                f16_round_slice(&mut b16);
                let c = &mut slab.get_mut(call.outputs[0])[call.out_offsets[0]..][..clen];
                match mm {
                    Matmul::Gemm { ta, tb, m, n, k, alpha, beta } => {
                        let (mta, mtb) = (to_math(ta), to_math(tb));
                        math::gemm(mta, mtb, m, n, k, alpha, &a16, &b16, beta, c);
                    }
                    Matmul::Gemv { trans, m, n, alpha, beta } => {
                        let t = if trans { math::Trans::Yes } else { math::Trans::No };
                        math::gemv(t, m, n, alpha, &a16, &b16, beta, c);
                    }
                }
                // Storage emulation: the result written back to DDR is
                // half precision too.
                f16_round_slice(c);
            }
        }
        Ok(true)
    }

    fn name(&self) -> &'static str {
        match self.precision {
            Precision::Fp32 => "quant-fp32-passthrough",
            Precision::Fp16 => "quant-fp16",
            Precision::Int8 => "quant-int8",
        }
    }
}

fn to_math(t: Trans) -> math::Trans {
    match t {
        Trans::No => math::Trans::No,
        Trans::Yes => math::Trans::Yes,
    }
}

/// Per-operand (min, max) ranges keyed by [`quant_key`], accumulated
/// over every matmul the calibration forwards execute.
pub type RangeMap = BTreeMap<String, [(f32, f32); 2]>;

/// Calibration-time observer: records matmul operand ranges and always
/// declines execution, so the fp32 forward is numerically untouched.
/// Clone handles share the underlying map.
#[derive(Clone, Default)]
pub struct RangeObserver {
    ranges: Arc<Mutex<RangeMap>>,
}

impl RangeObserver {
    pub fn new() -> RangeObserver {
        RangeObserver::default()
    }

    /// The ranges observed so far.
    pub fn snapshot(&self) -> RangeMap {
        self.ranges.lock().expect("range map lock").clone()
    }
}

impl NumericBackend for RangeObserver {
    fn execute(&mut self, slab: &mut Slab, call: &KernelCall) -> anyhow::Result<bool> {
        if let (Some(mm), Some(key)) = (Matmul::of(&call.kernel), quant_key(&call.kernel)) {
            let (alen, blen, _) = mm.lens();
            let ra = minmax(&slab.get(call.inputs[0])[call.in_offsets[0]..][..alen]);
            let rb = minmax(&slab.get(call.inputs[1])[call.in_offsets[1]..][..blen]);
            let mut map = self.ranges.lock().expect("range map lock");
            let entry = map
                .entry(key)
                .or_insert([(f32::INFINITY, f32::NEG_INFINITY); 2]);
            entry[0] = (entry[0].0.min(ra.0), entry[0].1.max(ra.1));
            entry[1] = (entry[1].0.min(rb.0), entry[1].1.max(rb.1));
        }
        Ok(false)
    }

    fn name(&self) -> &'static str {
        "quant-range-observer"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::cpu::CpuDevice;
    use crate::device::{BufId, Device};

    fn dev_with(
        backend: Box<dyn NumericBackend>,
        bufs: &[&[f32]],
    ) -> (CpuDevice, Vec<BufId>) {
        let mut dev = CpuDevice::new().with_backend(backend);
        let ids = bufs
            .iter()
            .map(|v| {
                let id = dev.alloc(v.len()).unwrap();
                dev.write(id, v);
                id
            })
            .collect();
        (dev, ids)
    }

    #[test]
    fn int8_backend_intercepts_gemm() {
        let a = [1.0f32, -2.0, 3.0, 4.0];
        let b = [0.5f32, 1.0, -1.0, 2.0];
        let backend = Box::new(QuantBackend::new(Precision::Int8, None));
        let (mut dev, ids) = dev_with(backend, &[&a, &b, &[0.0; 4]]);
        dev.launch(&KernelCall::new(
            Kernel::GemmNN { m: 2, n: 2, k: 2, alpha: 1.0, beta: 0.0 },
            &[ids[0], ids[1]],
            &[ids[2]],
        ))
        .unwrap();
        let mut out = [0.0f32; 4];
        dev.read(ids[2], &mut out);
        // fp32 result: [[ 2.5, -3.0 ], [ -2.5, 11.0 ]]; int8 emulation
        // must land within the quantization error envelope.
        let expect = [2.5f32, -3.0, -2.5, 11.0];
        for (o, e) in out.iter().zip(expect) {
            assert!((o - e).abs() < 0.25, "got {out:?}, want ≈{expect:?}");
        }
    }

    #[test]
    fn fp16_backend_rounds_through_grid() {
        // Values exactly representable in f16 multiply exactly.
        let a = [2.0f32, 0.5];
        let b = [4.0f32, 8.0];
        let backend = Box::new(QuantBackend::new(Precision::Fp16, None));
        let (mut dev, ids) = dev_with(backend, &[&a, &b, &[0.0; 1]]);
        dev.launch(&KernelCall::new(
            Kernel::GemmNN { m: 1, n: 1, k: 2, alpha: 1.0, beta: 0.0 },
            &[ids[0], ids[1]],
            &[ids[2]],
        ))
        .unwrap();
        let mut out = [0.0f32; 1];
        dev.read(ids[2], &mut out);
        assert_eq!(out[0], 12.0);
    }

    #[test]
    fn fp32_and_non_matmul_fall_through_to_native() {
        let backend = Box::new(QuantBackend::new(Precision::Fp32, None));
        let (mut dev, ids) = dev_with(backend, &[&[-1.0, 2.0]]);
        dev.launch(&KernelCall::new(
            Kernel::ReluF { n: 2, slope: 0.0 },
            &[ids[0]],
            &[ids[0]],
        ))
        .unwrap();
        let mut out = [0.0f32; 2];
        dev.read(ids[0], &mut out);
        assert_eq!(out, [0.0, 2.0]);
    }

    #[test]
    fn observer_records_ranges_without_changing_results() {
        let obs = RangeObserver::new();
        let a = [1.0f32, -2.0, 3.0, 4.0];
        let b = [5.0f32, 6.0, 7.0, 8.0];
        let (mut dev, ids) = dev_with(Box::new(obs.clone()), &[&a, &b, &[0.0; 4]]);
        dev.launch(&KernelCall::new(
            Kernel::GemmNN { m: 2, n: 2, k: 2, alpha: 1.0, beta: 0.0 },
            &[ids[0], ids[1]],
            &[ids[2]],
        ))
        .unwrap();
        let mut out = [0.0f32; 4];
        dev.read(ids[2], &mut out);
        // Native math ran: A=[[1,-2],[3,4]], B=[[5,6],[7,8]].
        assert_eq!(out, [-9.0, -10.0, 43.0, 50.0]);
        let map = obs.snapshot();
        assert_eq!(map.len(), 1);
        let ranges = map.values().next().unwrap();
        assert_eq!(ranges[0], (-2.0, 4.0));
        assert_eq!(ranges[1], (5.0, 8.0));
    }
}
