//! Pass 5: solver schedule and train→deploy schema checks.
//!
//! * `NL0401` — `lr_policy` not in [`crate::proto::LR_POLICIES`]
//!   (`Solver::learning_rate_at` would bail mid-training);
//! * `NL0402` — degenerate schedule: the policy parses but never changes
//!   the learning rate the way its parameters suggest (`step` with
//!   `stepsize` 0, `exp`/`inv` with `gamma` 0, `poly` with `max_iter` 0,
//!   empty `multistep` boundaries);
//! * `NL0403` — `multistep` boundaries not strictly ascending;
//! * `NL0411` — the train net's parameter schema cannot satisfy
//!   [`crate::net::WeightSnapshot::project`] onto its derived deploy
//!   net: a deploy layer's `(owner, slot)` key is missing from the train
//!   schema, or the element counts differ. This is the exact failure
//!   `fecaffe serve` would hit when adopting a snapshot trained from the
//!   same prototxt.

use super::{LintDiagnostic, LintOptions};
use crate::proto::{NetParameter, Phase, LR_POLICIES};
use std::collections::HashMap;

pub fn check(param: &NetParameter, opts: &LintOptions, diags: &mut Vec<LintDiagnostic>) {
    if let Some(s) = &opts.solver {
        if !LR_POLICIES.contains(&s.lr_policy.as_str()) {
            diags.push(
                LintDiagnostic::error(
                    "NL0401",
                    None,
                    format!("unknown lr_policy '{}'", s.lr_policy),
                )
                .with_help(format!("valid policies: {}", LR_POLICIES.join(", "))),
            );
        }
        let degenerate = match s.lr_policy.as_str() {
            "step" if s.stepsize == 0 => {
                Some("lr_policy 'step' with stepsize 0 decays every iteration".to_string())
            }
            "exp" | "inv" if s.gamma == 0.0 => Some(format!(
                "lr_policy '{}' with gamma 0 zeroes the learning rate immediately",
                s.lr_policy
            )),
            "poly" if s.max_iter == 0 => {
                Some("lr_policy 'poly' with max_iter 0 has no decay horizon".to_string())
            }
            "multistep" if s.stepvalue.is_empty() => {
                Some("lr_policy 'multistep' with no stepvalue boundaries never decays".to_string())
            }
            _ => None,
        };
        if let Some(msg) = degenerate {
            diags.push(LintDiagnostic::warning("NL0402", None, msg));
        }
        if s.lr_policy == "multistep" && s.stepvalue.windows(2).any(|w| w[0] >= w[1]) {
            diags.push(LintDiagnostic::error(
                "NL0403",
                None,
                format!(
                    "multistep boundaries must be strictly ascending, got {:?}",
                    s.stepvalue
                ),
            ));
        }
    }

    if opts.check_deploy_projection {
        check_projection(param, diags);
    }
}

/// Build the train-phase parameter schema and verify every `(owner,
/// slot)` key the derived deploy net will ask `WeightSnapshot::project`
/// for exists with the same element count.
fn check_projection(param: &NetParameter, diags: &mut Vec<LintDiagnostic>) {
    let schema_of = |p: &NetParameter, phase: Phase| -> Option<Vec<((String, usize), usize)>> {
        let layers: Vec<_> = p.layers_for_phase(phase).into_iter().cloned().collect();
        let with_splits = crate::net::insert_splits(&layers);
        let mut sink = Vec::new();
        let shapes = super::shapes::infer_with_splits(&with_splits, &p.inputs, None, &mut sink);
        if sink.iter().any(|d| d.severity == super::Severity::Error) {
            return None; // geometry findings already reported by pass 2
        }
        Some(super::shapes::param_schema(&with_splits, &shapes))
    };

    let train: HashMap<(String, usize), usize> = match schema_of(param, Phase::Train) {
        Some(s) => s.into_iter().collect(),
        None => return,
    };
    let dep = match crate::zoo::deploy(param, 1) {
        Ok(d) => d,
        Err(e) => {
            diags.push(LintDiagnostic::error(
                "NL0411",
                None,
                format!("cannot derive a deploy net for projection check: {e:#}"),
            ));
            return;
        }
    };
    let deploy_schema = match schema_of(&dep.param, Phase::Test) {
        Some(s) => s,
        None => return,
    };
    for ((owner, slot), len) in deploy_schema {
        match train.get(&(owner.clone(), slot)) {
            None => diags.push(
                LintDiagnostic::error(
                    "NL0411",
                    Some(owner.as_str()),
                    format!(
                        "deploy net needs parameter ({owner}, {slot}) that the train \
                         net never learns"
                    ),
                )
                .with_help("WeightSnapshot::project onto this deploy net will fail"),
            ),
            Some(&tl) if tl != len => diags.push(
                LintDiagnostic::error(
                    "NL0411",
                    Some(owner.as_str()),
                    format!(
                        "parameter ({owner}, {slot}) has {tl} elements in the train net \
                         but {len} in the deploy net"
                    ),
                )
                .with_help("WeightSnapshot::project onto this deploy net will fail"),
            ),
            _ => {}
        }
    }
}
