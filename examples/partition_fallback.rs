//! Workload partitioning across devices (paper §3.3 memory sync +
//! §5.2 CPU-fallback optimization): run the same LeNet batch on
//! (a) the FPGA simulator, (b) the CPU device, and (c) verify the
//! syncedmem state machine moves data correctly between host and device
//! by cross-checking numerics blob-by-blob.
//!
//!     cargo run --release --example partition_fallback

use fecaffe::blob::MemState;
use fecaffe::device::cpu::CpuDevice;
use fecaffe::device::fpga::FpgaSimDevice;
use fecaffe::device::Device;
use fecaffe::net::Net;
use fecaffe::proto::Phase;
use fecaffe::zoo;

fn main() -> anyhow::Result<()> {
    let param = zoo::by_name("lenet", 4)?;

    // (a) FPGA path.
    let mut fpga = FpgaSimDevice::new();
    let mut net_f = Net::from_param(&param, Phase::Train, &mut fpga)?;
    let loss_f = net_f.forward_backward(&mut fpga)?;

    // (b) CPU fallback path (same deterministic init + data stream).
    let mut cpu = CpuDevice::new();
    let mut net_c = Net::from_param(&param, Phase::Train, &mut cpu)?;
    let loss_c = net_c.forward_backward(&mut cpu)?;

    println!("loss  fpga-sim: {loss_f:.6}   cpu: {loss_c:.6}");
    anyhow::ensure!(
        (loss_f - loss_c).abs() < 1e-3,
        "device paths diverged: {loss_f} vs {loss_c}"
    );

    // (c) Blob-by-blob equivalence + state machine demo.
    let mut worst = 0.0f32;
    for name in net_f.blob_names() {
        let bf = net_f.blob(&name).unwrap();
        let bc = net_c.blob(&name).unwrap();
        // Reading host data performs the FPGA→CPU sync (to_cpu).
        let state_before = bf.borrow().data.state();
        let vf = bf.borrow_mut().data_vec(&mut fpga);
        let state_after = bf.borrow().data.state();
        let vc = bc.borrow_mut().data_vec(&mut cpu);
        for (a, b) in vf.iter().zip(vc.iter()) {
            worst = worst.max((a - b).abs());
        }
        if name == "conv1" {
            println!(
                "syncedmem '{name}': {state_before:?} -> read -> {state_after:?} \
                 (paper Fig.3 FPGA->Synced transition)"
            );
            assert_eq!(state_after, MemState::Synced);
        }
    }
    println!("max |fpga - cpu| over all blobs: {worst:.2e}");
    anyhow::ensure!(worst < 1e-2, "numeric divergence {worst}");

    // Partition accounting: how much PCIe traffic did the FPGA run pay?
    use fecaffe::device::KClass;
    let stats = fpga.profiler.stats();
    let writes = stats.get(&KClass::WriteBuffer).map(|s| s.instances).unwrap_or(0);
    let reads = stats.get(&KClass::ReadBuffer).map(|s| s.instances).unwrap_or(0);
    println!(
        "PCIe events on the FPGA path: {writes} writes, {reads} reads \
         (CPU fallback pays none — the §5.2 trade-off)"
    );
    println!("partition_fallback OK");
    Ok(())
}
