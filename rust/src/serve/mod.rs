//! `serve` — a batched, multi-worker inference serving engine.
//!
//! The path from "a trained zoo model" to "serving heavy traffic":
//!
//! ```text
//!             submit()                          dispatch
//!  clients ──────────────▶ [admission queue] ─▶ batcher ─▶ [batch queue] ─▶ worker 0..N-1
//!             non-blocking   bounded:             deadline-aware             each: Net replica
//!             ResponseHandle backpressure         micro-batching             + own Device
//!                            (Overloaded)         (max_batch, max_linger)         │
//!  ResponseHandle::wait() ◀──────────── result scatter (one output row per request)
//! ```
//!
//! * **Admission control** — `Engine::submit` pushes into a bounded
//!   queue and returns `Err(Overloaded)` when it's full, so overload
//!   surfaces to callers instead of growing tail latency.
//! * **Micro-batching** — the batcher coalesces single-sample requests
//!   into one batched input blob (up to `max_batch`), flushing early
//!   when the oldest request has lingered `max_linger`. Per-sample math
//!   in every layer is batch-invariant, so batched outputs are
//!   bit-identical to sequential single-sample forwards (see
//!   `tests/integration_serve.rs`).
//! * **Worker pool, dynamic shapes** — N threads, each owning ONE
//!   shape-polymorphic `Net` replica bound to its own device (CPU or
//!   FPGA sim). The replica is pre-built at `max_batch` (nothing is
//!   constructed on the serving path) and *reshaped* per batch to the
//!   popped batch's bucketed row count
//!   ([`crate::runtime::plan::batch_bucket`]: next power of two, capped
//!   at `max_batch`), so partial batches cost what they compute — at
//!   most 2× the filled rows — never a pad to `max_batch`. Replicas
//!   adopt one shared [`crate::net::WeightSnapshot`] (`Arc`-shared host
//!   weights); activations stay per-worker and grow-only.
//! * **Metrics** — wait-free counters, a log2 latency histogram
//!   (p50/p95/p99 plus exact bucket bounds), queue-depth gauges
//!   (current + high-water) and `batch_occupancy` (filled rows /
//!   executed rows — how much of the executed compute carried real
//!   requests); exact quantiles for load tests come from
//!   [`crate::util::stats`]. `GET /metrics` serves JSON or, with
//!   `?format=prometheus`, Prometheus text exposition.
//! * **Tracing** — `EngineConfig::trace_sample = N` samples every Nth
//!   batch into a ring of [`crate::obs::BatchTrace`]s: queue wait,
//!   batch assembly, reshape, per-layer forward, device (pcie /
//!   fpga-kernel) and scatter spans on one timeline, dumped as
//!   chrome-trace JSON from `GET /admin/trace` (open in Perfetto).
//!   Off (`0`) by default and wait-free when off.
//! * **Multi-model routing** — a [`router::ModelRouter`] owns one
//!   engine per model with the worker/intra-op budget split across
//!   them, and [`http::HttpServer`] puts the whole stack behind a
//!   std-only HTTP/1.1 front-end (`POST /v1/models/<name>:predict`,
//!   `GET /metrics`, `GET /healthz`) so load lives outside the process.
//! * **Weight hot-swap** — [`Engine::publish_weights`] atomically swaps
//!   a new versioned [`crate::net::WeightSnapshot`] behind the
//!   admission path (a live training solver is the usual publisher:
//!   `fecaffe train --serve`, or `POST /admin/models/<name>:publish`
//!   from a snapshot file). Workers adopt at their next batch boundary,
//!   so no request is dropped and no response mixes weight versions;
//!   every response and `/metrics` report carries `weights_version`.
//!
//! See the `serve` binary (`cargo run --release --bin serve`) for the
//! CLI and `benches/serve_throughput.rs` for the standing benchmark.

pub mod batcher;
pub mod engine;
pub mod http;
pub mod metrics;
pub mod router;
mod queue;
mod worker;

pub use batcher::BatcherConfig;
pub use engine::{
    DeviceKind, Engine, EngineConfig, PublishError, Response, ResponseHandle, ServeError,
};
pub use http::{http_load_test, http_request, HttpClient, HttpConfig, HttpServer};
pub use metrics::{Histogram, Metrics, MetricsReport};
pub use router::{ModelRouter, RouteError, RouterConfig};

use crate::util::prng::Pcg32;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Acquire a mutex, recovering the guard if a previous holder panicked.
///
/// Sound only for mutexes whose protected state is valid at every await
/// point (every `serve` mutex qualifies: queues, the weights slot, the
/// response slots — each holds a complete value, never a half-built
/// one). Without this, one worker panic poisons a shared lock and
/// cascades `unwrap` panics through every other thread touching it —
/// exactly the failure amplification a supervised pool must not have.
pub(crate) fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Result of [`load_test`].
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Requests that completed successfully.
    pub requests: u64,
    /// Requests that failed (worker error, or submit refused outright).
    pub failed: u64,
    /// Requests shed because their deadline expired before execution
    /// (HTTP 504 semantics) — not failures; nothing broke.
    pub shed_expired: u64,
    /// Submit attempts that hit backpressure and were retried.
    pub backpressure_retries: u64,
    /// Submit attempts fast-rejected by an open circuit breaker and
    /// retried after the hinted cooldown.
    pub breaker_retries: u64,
    pub wall: Duration,
    /// Completed requests per second of wall time.
    pub rps: f64,
    /// Per-request submit→response latencies, nanoseconds (unsorted;
    /// successful requests only).
    pub latencies_ns: Vec<f64>,
}

/// Closed-loop self-driven load test: `clients` threads submit `total`
/// random single-sample requests and wait for every response, retrying
/// (with a short backoff) when the engine applies backpressure. Failures
/// are counted, not fatal, so a report always comes back.
pub fn load_test(engine: &Engine, clients: usize, total: usize, seed: u64) -> LoadReport {
    let clients = clients.max(1);
    let issued = AtomicUsize::new(0);
    let retries = AtomicU64::new(0);
    let breaker_retries = AtomicU64::new(0);
    let failed = AtomicU64::new(0);
    let shed = AtomicU64::new(0);
    let t0 = Instant::now();
    let latencies_ns: Vec<f64> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(clients);
        for cid in 0..clients {
            let issued = &issued;
            let retries = &retries;
            let breaker_retries = &breaker_retries;
            let failed = &failed;
            let shed = &shed;
            handles.push(scope.spawn(move || {
                let mut rng = Pcg32::with_stream(seed, cid as u64 + 1);
                let mut lats = Vec::new();
                'requests: loop {
                    // Ticket per request; stop when the budget is spent.
                    if issued.fetch_add(1, Ordering::Relaxed) >= total {
                        break;
                    }
                    let mut sample = vec![0f32; engine.sample_len()];
                    rng.fill_uniform(&mut sample, 0.0, 1.0);
                    let handle = loop {
                        match engine.submit(sample) {
                            Ok(h) => break h,
                            Err(ServeError::Overloaded(rejected)) => {
                                // Backpressure hands the sample back —
                                // retry without recloning it.
                                sample = rejected;
                                retries.fetch_add(1, Ordering::Relaxed);
                                std::thread::sleep(Duration::from_micros(200));
                            }
                            Err(ServeError::BreakerOpen { retry_after_ms }) => {
                                // Open circuit: wait out (a slice of) the
                                // hinted cooldown, then retry — a breaker
                                // that re-closes must not count as client
                                // failures. The sample is consumed by the
                                // error path, so regenerate it from the
                                // same rng stream.
                                breaker_retries.fetch_add(1, Ordering::Relaxed);
                                std::thread::sleep(Duration::from_millis(
                                    retry_after_ms.clamp(1, 50),
                                ));
                                sample = vec![0f32; engine.sample_len()];
                                rng.fill_uniform(&mut sample, 0.0, 1.0);
                            }
                            Err(_) => {
                                // Engine refused outright (shutting down,
                                // schema mismatch): count and give up on
                                // this client — retrying can't succeed.
                                failed.fetch_add(1, Ordering::Relaxed);
                                break 'requests;
                            }
                        }
                    };
                    match handle.wait() {
                        Ok(resp) => lats.push(resp.latency.as_nanos() as f64),
                        Err(ServeError::DeadlineExceeded) => {
                            // Shed, not failed: the latency budget ran
                            // out, which is the contract working.
                            shed.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => {
                            failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                lats
            }));
        }
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("load_test client panicked"))
            .collect()
    });
    let wall = t0.elapsed();
    let requests = latencies_ns.len() as u64;
    LoadReport {
        requests,
        failed: failed.load(Ordering::Relaxed),
        shed_expired: shed.load(Ordering::Relaxed),
        backpressure_retries: retries.load(Ordering::Relaxed),
        breaker_retries: breaker_retries.load(Ordering::Relaxed),
        wall,
        rps: requests as f64 / wall.as_secs_f64().max(1e-9),
        latencies_ns,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `lock_unpoisoned` recovers the guard (and the protected value)
    /// after a holder panicked — the primitive behind the serve-wide
    /// mutex-poisoning audit.
    #[test]
    fn lock_unpoisoned_recovers_state_after_a_panicked_holder() {
        let shared = std::sync::Arc::new(Mutex::new(41));
        let poisoner = shared.clone();
        let _ = std::thread::spawn(move || {
            let mut g = poisoner.lock().unwrap();
            *g = 42; // completed write — the state stays valid
            panic!("poison while holding the lock");
        })
        .join();
        assert!(shared.lock().is_err(), "precondition: mutex is poisoned");
        assert_eq!(*lock_unpoisoned(&shared), 42);
        *lock_unpoisoned(&shared) += 1;
        assert_eq!(*lock_unpoisoned(&shared), 43);
    }
}
