//! Model zoo: programmatic builders for the paper's five networks
//! (LeNet, AlexNet, VGG-16, SqueezeNet v1.0, GoogLeNet v1), matching the
//! BVLC train_val prototxts layer-for-layer. `emit::emit_net` turns any
//! of them back into standard prototxt (and the parser round-trips them —
//! see the property suite).

pub mod lenet;
pub mod alexnet;
pub mod vgg;
pub mod squeezenet;
pub mod googlenet;

use crate::proto::*;

/// All networks the zoo provides (paper Table 4 "Network Topologies
/// Supported" row).
pub const NETWORKS: &[&str] = &["lenet", "alexnet", "vgg16", "squeezenet", "googlenet"];

/// Build a train_val network by name with the given train batch size.
pub fn by_name(name: &str, batch: usize) -> anyhow::Result<NetParameter> {
    match name {
        "lenet" => Ok(lenet::lenet(batch)),
        "alexnet" => Ok(alexnet::alexnet(batch)),
        "vgg16" => Ok(vgg::vgg16(batch)),
        "squeezenet" => Ok(squeezenet::squeezenet(batch)),
        "googlenet" => Ok(googlenet::googlenet(batch)),
        other => anyhow::bail!(
            "unknown network '{other}' (have: {})",
            NETWORKS.join(", ")
        ),
    }
}

/// Paper-style default solver for a network (Table 4: "BS:32 and Default
/// Solver" etc.).
pub fn default_solver(name: &str) -> anyhow::Result<SolverParameter> {
    let mut s = SolverParameter::default();
    s.net = name.to_string();
    match name {
        "lenet" => {
            s.base_lr = 0.01;
            s.lr_policy = "inv".into();
            s.gamma = 1e-4;
            s.power = 0.75;
            s.momentum = 0.9;
            s.weight_decay = 5e-4;
            s.max_iter = 500;
            s.display = 50;
        }
        "alexnet" => {
            s.base_lr = 0.01;
            s.lr_policy = "step".into();
            s.gamma = 0.1;
            s.stepsize = 100_000;
            s.momentum = 0.9;
            s.weight_decay = 5e-4;
        }
        "vgg16" => {
            s.base_lr = 0.001;
            s.lr_policy = "step".into();
            s.gamma = 0.1;
            s.stepsize = 100_000;
            s.momentum = 0.9;
            s.weight_decay = 5e-4;
        }
        "squeezenet" => {
            s.base_lr = 0.04;
            s.lr_policy = "poly".into();
            s.power = 1.0;
            s.momentum = 0.9;
            s.weight_decay = 2e-4;
        }
        "googlenet" => {
            // Paper §Table 4: "Default Solver with Adam".
            s.kind = SolverKind::Adam;
            s.base_lr = 0.001;
            s.lr_policy = "fixed".into();
            s.momentum = 0.9;
            s.momentum2 = 0.999;
            s.weight_decay = 2e-4;
        }
        other => anyhow::bail!("no default solver for '{other}'"),
    }
    Ok(s)
}

// ----------------------------------------------------------------- deploy

/// A deploy-style (inference-only) net derived from a train_val net:
/// explicit `input` blob instead of a data layer, label-consuming layers
/// (loss, accuracy) stripped, and a `Softmax` head producing
/// probabilities. This is what the serving engine replicates per worker.
#[derive(Debug, Clone)]
pub struct DeployNet {
    pub param: NetParameter,
    /// Name of the input blob to fill before `forward`.
    pub input: String,
    /// Name of the output blob to read after `forward`.
    pub output: String,
    /// Batch size the input blob is shaped for.
    pub batch: usize,
    /// Per-sample input shape (C, H, W).
    pub sample_shape: [usize; 3],
    /// Elements per input sample (C*H*W).
    pub sample_len: usize,
}

/// Derive a deploy net at the given batch size from a train_val net
/// (zoo builder output or parsed prototxt). Nets that already use
/// deploy-style explicit inputs are re-batched instead.
pub fn deploy(train: &NetParameter, batch: usize) -> anyhow::Result<DeployNet> {
    anyhow::ensure!(batch >= 1, "deploy: batch must be >= 1");
    let mut param = NetParameter {
        name: format!("{}_deploy", train.name),
        ..Default::default()
    };

    let mut score_blob: Option<String> = None;
    let mut data_shape: Option<[usize; 3]> = None;
    let mut input_name = "data".to_string();
    for lp in train.layers_for_phase(Phase::Test) {
        match lp.kind.as_str() {
            "SyntheticData" | "Data" => {
                let p = lp.data.as_ref().ok_or_else(|| {
                    anyhow::anyhow!("deploy: data layer '{}' has no data_param", lp.name)
                })?;
                data_shape = Some([p.channels, p.height, p.width]);
                if let Some(t) = lp.tops.first() {
                    input_name = t.clone();
                }
            }
            // Label consumers are dropped; the *last* loss names the
            // score blob the Softmax head attaches to (GoogLeNet's aux
            // heads come first, the main classifier last).
            "SoftmaxWithLoss" => {
                score_blob = lp.bottoms.first().cloned();
            }
            "Accuracy" => {}
            _ => param.layers.push(lp.clone()),
        }
    }

    let (input, sample_shape) = if let Some((name, shape)) = train.inputs.first() {
        // Already deploy-style: re-batch the first input, keep the rest.
        let mut s = *shape;
        s[0] = batch;
        param.inputs.push((name.clone(), s));
        for (n, sh) in train.inputs.iter().skip(1) {
            param.inputs.push((n.clone(), *sh));
        }
        (name.clone(), [shape[1], shape[2], shape[3]])
    } else {
        let [c, h, w] = data_shape.ok_or_else(|| {
            anyhow::anyhow!("deploy: net '{}' has neither a data layer nor inputs", train.name)
        })?;
        param.inputs.push((input_name.clone(), [batch, c, h, w]));
        (input_name, [c, h, w])
    };

    let output = match score_blob {
        Some(score) => {
            let mut sm = LayerParameter::new("prob", "Softmax");
            sm.bottoms = vec![score];
            sm.tops = vec!["prob".into()];
            param.layers.push(sm);
            "prob".to_string()
        }
        None => param
            .layers
            .last()
            .and_then(|l| l.tops.first().cloned())
            .ok_or_else(|| anyhow::anyhow!("deploy: net '{}' has no layers", train.name))?,
    };

    // Prune layers with no path to the output — stripping a loss leaves
    // its upstream branch dangling (GoogLeNet's aux classifier heads are
    // ~half the parameters), and Caffe deploy prototxts drop them too.
    // Reverse reachability over blob names handles in-place chains.
    let mut needed: std::collections::HashSet<String> =
        std::iter::once(output.clone()).collect();
    let mut keep = vec![false; param.layers.len()];
    for (i, lp) in param.layers.iter().enumerate().rev() {
        if lp.tops.iter().any(|t| needed.contains(t)) {
            keep[i] = true;
            for b in &lp.bottoms {
                needed.insert(b.clone());
            }
        }
    }
    let mut keep_it = keep.iter();
    param.layers.retain(|_| *keep_it.next().expect("keep mask aligned"));

    let sample_len = sample_shape.iter().product();
    Ok(DeployNet { param, input, output, batch, sample_shape, sample_len })
}

/// Convenience: deploy net for a zoo network by name.
pub fn deploy_by_name(name: &str, batch: usize) -> anyhow::Result<DeployNet> {
    deploy(&by_name(name, 1)?, batch)
}

// ---------------------------------------------------------------- builder

/// Small fluent builder the per-net modules share.
pub struct NetBuilder {
    pub net: NetParameter,
}

impl NetBuilder {
    pub fn new(name: &str) -> NetBuilder {
        NetBuilder {
            net: NetParameter { name: name.into(), layers: Vec::new(), inputs: Vec::new() },
        }
    }

    pub fn finish(self) -> NetParameter {
        self.net
    }

    pub fn data(
        &mut self,
        batch: usize,
        channels: usize,
        hw: usize,
        num_classes: usize,
        source: &str,
    ) -> &mut Self {
        let mut l = LayerParameter::new("data", "SyntheticData");
        l.tops = vec!["data".into(), "label".into()];
        l.data = Some(SyntheticDataParameter {
            batch_size: batch,
            channels,
            height: hw,
            width: hw,
            num_classes,
            source: source.into(),
            seed: 1,
        });
        self.net.layers.push(l);
        self
    }

    #[allow(clippy::too_many_arguments)]
    pub fn conv_full(
        &mut self,
        name: &str,
        bottom: &str,
        top: &str,
        num_output: usize,
        kernel: usize,
        stride: usize,
        pad: usize,
        group: usize,
        filler: FillerParameter,
    ) -> &mut Self {
        let mut l = LayerParameter::new(name, "Convolution");
        l.bottoms = vec![bottom.into()];
        l.tops = vec![top.into()];
        l.params = vec![
            ParamSpec { lr_mult: 1.0, decay_mult: 1.0 },
            ParamSpec { lr_mult: 2.0, decay_mult: 0.0 },
        ];
        let mut c = ConvolutionParameter::default();
        c.num_output = num_output;
        c.kernel_h = kernel;
        c.kernel_w = kernel;
        c.stride_h = stride;
        c.stride_w = stride;
        c.pad_h = pad;
        c.pad_w = pad;
        c.group = group;
        c.weight_filler = filler;
        c.bias_filler = FillerParameter::default();
        l.conv = Some(c);
        self.net.layers.push(l);
        self
    }

    pub fn conv(
        &mut self,
        name: &str,
        bottom: &str,
        num_output: usize,
        kernel: usize,
        stride: usize,
        pad: usize,
    ) -> &mut Self {
        self.conv_full(name, bottom, name, num_output, kernel, stride, pad, 1, xavier())
    }

    /// conv + in-place ReLU (the zoo's nets always pair them).
    pub fn conv_relu(
        &mut self,
        name: &str,
        bottom: &str,
        num_output: usize,
        kernel: usize,
        stride: usize,
        pad: usize,
    ) -> &mut Self {
        self.conv(name, bottom, num_output, kernel, stride, pad);
        self.relu_inplace(&format!("relu_{name}"), name)
    }

    pub fn relu_inplace(&mut self, name: &str, blob: &str) -> &mut Self {
        let mut l = LayerParameter::new(name, "ReLU");
        l.bottoms = vec![blob.into()];
        l.tops = vec![blob.into()];
        self.net.layers.push(l);
        self
    }

    #[allow(clippy::too_many_arguments)]
    pub fn pool(
        &mut self,
        name: &str,
        bottom: &str,
        method: PoolMethod,
        kernel: usize,
        stride: usize,
        pad: usize,
    ) -> &mut Self {
        let mut l = LayerParameter::new(name, "Pooling");
        l.bottoms = vec![bottom.into()];
        l.tops = vec![name.into()];
        l.pool = Some(PoolingParameter {
            method,
            kernel_h: kernel,
            kernel_w: kernel,
            stride_h: stride,
            stride_w: stride,
            pad_h: pad,
            pad_w: pad,
            global_pooling: false,
        });
        self.net.layers.push(l);
        self
    }

    pub fn global_ave_pool(&mut self, name: &str, bottom: &str) -> &mut Self {
        let mut l = LayerParameter::new(name, "Pooling");
        l.bottoms = vec![bottom.into()];
        l.tops = vec![name.into()];
        let mut p = PoolingParameter::default();
        p.method = PoolMethod::Ave;
        p.global_pooling = true;
        l.pool = Some(p);
        self.net.layers.push(l);
        self
    }

    pub fn lrn(&mut self, name: &str, bottom: &str) -> &mut Self {
        let mut l = LayerParameter::new(name, "LRN");
        l.bottoms = vec![bottom.into()];
        l.tops = vec![name.into()];
        l.lrn = Some(LrnParameter { local_size: 5, alpha: 1e-4, beta: 0.75, k: 1.0 });
        self.net.layers.push(l);
        self
    }

    pub fn fc(&mut self, name: &str, bottom: &str, num_output: usize) -> &mut Self {
        let mut l = LayerParameter::new(name, "InnerProduct");
        l.bottoms = vec![bottom.into()];
        l.tops = vec![name.into()];
        l.params = vec![
            ParamSpec { lr_mult: 1.0, decay_mult: 1.0 },
            ParamSpec { lr_mult: 2.0, decay_mult: 0.0 },
        ];
        l.inner_product = Some(InnerProductParameter {
            num_output,
            bias_term: true,
            weight_filler: xavier(),
            bias_filler: FillerParameter::default(),
        });
        self.net.layers.push(l);
        self
    }

    pub fn dropout_inplace(&mut self, name: &str, blob: &str, ratio: f32) -> &mut Self {
        let mut l = LayerParameter::new(name, "Dropout");
        l.bottoms = vec![blob.into()];
        l.tops = vec![blob.into()];
        l.dropout = Some(DropoutParameter { dropout_ratio: ratio });
        self.net.layers.push(l);
        self
    }

    pub fn concat(&mut self, name: &str, bottoms: &[&str]) -> &mut Self {
        let mut l = LayerParameter::new(name, "Concat");
        l.bottoms = bottoms.iter().map(|s| s.to_string()).collect();
        l.tops = vec![name.into()];
        l.concat = Some(ConcatParameter { axis: 1 });
        self.net.layers.push(l);
        self
    }

    pub fn softmax_loss(&mut self, name: &str, scores: &str, weight: f32) -> &mut Self {
        let mut l = LayerParameter::new(name, "SoftmaxWithLoss");
        l.bottoms = vec![scores.into(), "label".into()];
        l.tops = vec![name.into()];
        if weight != 1.0 {
            l.loss_weight = vec![weight];
        }
        self.net.layers.push(l);
        self
    }

    pub fn accuracy(&mut self, name: &str, scores: &str) -> &mut Self {
        let mut l = LayerParameter::new(name, "Accuracy");
        l.bottoms = vec![scores.into(), "label".into()];
        l.tops = vec![name.into()];
        l.phase = Some(Phase::Test);
        self.net.layers.push(l);
        self
    }
}

pub fn xavier() -> FillerParameter {
    FillerParameter { kind: "xavier".into(), ..Default::default() }
}

pub fn gaussian(std: f32) -> FillerParameter {
    FillerParameter { kind: "gaussian".into(), std, ..Default::default() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::{emit, parse_net};

    #[test]
    fn registry_builds_every_network() {
        for name in NETWORKS {
            let net = by_name(name, 1).unwrap();
            assert!(!net.layers.is_empty(), "{name}");
            // prototxt round-trip
            let text = emit::emit_net(&net);
            let back = parse_net(&text).unwrap();
            assert_eq!(net, back, "{name} prototxt round-trip");
        }
        assert!(by_name("resnet", 1).is_err());
    }

    #[test]
    fn default_solvers_exist() {
        for name in NETWORKS {
            let s = default_solver(name).unwrap();
            assert!(s.base_lr > 0.0);
        }
    }

    #[test]
    fn googlenet_uses_adam_by_default() {
        let s = default_solver("googlenet").unwrap();
        assert_eq!(s.kind, SolverKind::Adam);
    }

    #[test]
    fn deploy_strips_training_layers() {
        let d = deploy_by_name("lenet", 4).unwrap();
        assert_eq!(d.batch, 4);
        assert_eq!(d.sample_shape, [1, 28, 28]);
        assert_eq!(d.sample_len, 28 * 28);
        assert_eq!(d.input, "data");
        assert_eq!(d.output, "prob");
        assert_eq!(d.param.inputs, vec![("data".to_string(), [4, 1, 28, 28])]);
        let kinds: Vec<&str> = d.param.layers.iter().map(|l| l.kind.as_str()).collect();
        assert!(!kinds.contains(&"SyntheticData"));
        assert!(!kinds.contains(&"SoftmaxWithLoss"));
        assert!(!kinds.contains(&"Accuracy"));
        assert_eq!(*kinds.last().unwrap(), "Softmax");
    }

    #[test]
    fn deploy_net_runs_and_softmax_rows_sum_to_one() {
        use crate::device::cpu::CpuDevice;
        use crate::net::Net;

        let d = deploy_by_name("lenet", 2).unwrap();
        let mut dev = CpuDevice::new();
        let mut net = Net::from_param(&d.param, Phase::Test, &mut dev).unwrap();
        let input = net.blob(&d.input).unwrap();
        assert_eq!(input.borrow().shape(), &[2, 1, 28, 28]);
        input
            .borrow_mut()
            .set_data(&mut dev, &vec![0.5; 2 * d.sample_len]);
        net.forward(&mut dev).unwrap();
        let out = net.blob(&d.output).unwrap().borrow_mut().data_vec(&mut dev);
        assert_eq!(out.len(), 2 * 10);
        for row in out.chunks(10) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "softmax row sum {s}");
        }
    }

    #[test]
    fn deploy_builds_for_every_zoo_network() {
        for name in NETWORKS {
            let d = deploy_by_name(name, 1).unwrap();
            assert!(!d.param.layers.is_empty(), "{name}");
            assert_eq!(d.output, "prob", "{name}");
        }
    }

    #[test]
    fn deploy_prunes_dead_branches() {
        // GoogLeNet's aux classifier heads hang off stripped losses —
        // they must not survive into the serving net.
        let d = deploy_by_name("googlenet", 1).unwrap();
        for l in &d.param.layers {
            assert!(
                !l.name.starts_with("loss1/") && !l.name.starts_with("loss2/"),
                "aux-head layer '{}' should be pruned",
                l.name
            );
        }
        // The main path survives intact up to the Softmax head.
        assert!(d.param.layers.iter().any(|l| l.name == "loss3/classifier"));
        assert_eq!(d.param.layers.last().unwrap().kind, "Softmax");
    }
}
