//! `serve` — batched, multi-worker inference serving for any zoo model.
//!
//! Three modes:
//!
//! ```text
//! # 1. In-process closed-loop load test (the original mode):
//! serve --net lenet --workers 4 --max-batch 32
//! serve --net lenet --device fpga --json BENCH_serve.json
//!
//! # 2. HTTP server: one engine per model behind a TcpListener.
//! #    Runs until `POST /admin/shutdown` (the SIGTERM equivalent),
//! #    then drains every admitted request before exiting.
//! serve --http 127.0.0.1:8080 --models lenet,alexnet --workers 4
//!
//! # 3. HTTP load generator against a running server (mode 2),
//! #    so load finally lives outside the serving process:
//! serve --target 127.0.0.1:8080 --net lenet --requests 512 --clients 8
//! ```

use fecaffe::serve::{
    http_load_test, http_request, load_test, DeviceKind, Engine, EngineConfig, HttpConfig,
    HttpServer, LoadReport, ModelRouter, RouterConfig,
};
use fecaffe::util::chaos::{FaultPlan, CHAOS_ENV};
use fecaffe::util::cli::{usage, Args, Spec};
use fecaffe::util::json::Json;
use fecaffe::util::stats::{fmt_ns, summarize, Summary};
use fecaffe::util::table::Table;
use fecaffe::zoo;
use std::sync::Arc;
use std::time::Duration;

const SPECS: &[Spec] = &[
    Spec::opt(
        "net",
        Some("lenet"),
        "zoo network name (optionally name@fp16 / name@int8) or net prototxt path",
    ),
    Spec::opt("workers", Some("4"), "worker replicas (threads; --http splits them across models)"),
    Spec::opt("max-batch", Some("32"), "micro-batch upper bound"),
    Spec::opt("linger-us", Some("2000"), "micro-batch linger deadline, microseconds"),
    Spec::opt("queue-cap", Some("1024"), "admission queue capacity (backpressure bound)"),
    Spec::opt("device", Some("cpu"), "worker device: cpu | fpga"),
    Spec::opt(
        "intra-op",
        Some("0"),
        "intra-op threads per worker (0 = split FECAFFE_THREADS evenly)",
    ),
    Spec::opt(
        "trace-sample",
        Some("0"),
        "sample every Nth batch into the trace ring for GET /admin/trace (0 = off)",
    ),
    Spec::opt("requests", Some("512"), "load-test request count"),
    Spec::opt("clients", Some("8"), "load-test client threads"),
    Spec::opt("json", None, "also write the report as JSON to this path"),
    Spec::opt(
        "http",
        None,
        "serve over HTTP on this address (e.g. 127.0.0.1:8080; port 0 picks one)",
    ),
    Spec::opt(
        "models",
        Some("lenet"),
        "comma-separated zoo models for --http mode; a name@int8 / name@fp16 \
         suffix serves that reduced-precision variant (e.g. lenet,lenet@int8)",
    ),
    Spec::opt(
        "chaos",
        None,
        "deterministic fault-injection plan, e.g. seed=7,fault=0.05,panic=1 \
         (overrides the FECAFFE_CHAOS env var; see README \"Fault tolerance\")",
    ),
    Spec::opt(
        "target",
        None,
        "run the HTTP load generator against a serve --http process at this address",
    ),
    Spec::opt(
        "aot-cache",
        None,
        "cold-boot engines from this AOT plan cache (`fecaffe aot build` output; \
         overrides the FECAFFE_AOT_CACHE env var)",
    ),
];

fn parse_device(args: &Args) -> anyhow::Result<DeviceKind> {
    match args.get("device").unwrap_or("cpu") {
        "cpu" => Ok(DeviceKind::Cpu),
        "fpga" => Ok(DeviceKind::FpgaSim),
        other => anyhow::bail!("unknown device '{other}' (cpu | fpga)"),
    }
}

/// `--chaos` fault plan, if any. `None` here still lets the engine pick
/// up the `FECAFFE_CHAOS` env var — the flag just takes precedence.
fn parse_chaos(args: &Args) -> anyhow::Result<Option<FaultPlan>> {
    match args.get("chaos") {
        None => Ok(None),
        Some(spec) => {
            let plan = FaultPlan::parse(spec)
                .map_err(|e| anyhow::anyhow!("--chaos '{spec}': {e}"))?;
            println!("[serve] chaos plan active: {spec}");
            Ok(Some(plan))
        }
    }
}

fn report_table(title: &str, report: &LoadReport, s: &Summary) -> Table {
    let mut table = Table::new(title, &["Metric", "Value"]);
    table.row(&["requests completed".into(), format!("{}", report.requests)]);
    table.row(&["wall time".into(), format!("{:.3} s", report.wall.as_secs_f64())]);
    table.row(&["throughput".into(), format!("{:.1} req/s", report.rps)]);
    table.row(&["latency p50".into(), fmt_ns(s.median_ns)]);
    table.row(&["latency p95".into(), fmt_ns(s.p95_ns)]);
    table.row(&["latency p99".into(), fmt_ns(s.p99_ns)]);
    table.row(&["latency mean".into(), fmt_ns(s.mean_ns)]);
    table.row(&[
        "backpressure retries".into(),
        format!("{}", report.backpressure_retries),
    ]);
    table.row(&["breaker retries".into(), format!("{}", report.breaker_retries)]);
    table.row(&["shed (deadline expired)".into(), format!("{}", report.shed_expired)]);
    table.row(&["failed requests".into(), format!("{}", report.failed)]);
    table
}

/// Mode 2: HTTP server over a multi-model router. Parks until a client
/// POSTs /admin/shutdown, then drains and exits.
fn run_http_server(args: &Args, addr: &str) -> anyhow::Result<()> {
    let models: Vec<&str> = args
        .get("models")
        .unwrap_or("lenet")
        .split(',')
        .map(|s| s.trim())
        .filter(|s| !s.is_empty())
        .collect();
    let cfg = RouterConfig {
        total_workers: args.get_usize("workers").map_err(anyhow::Error::msg)?,
        max_batch: args.get_usize("max-batch").map_err(anyhow::Error::msg)?,
        max_linger: Duration::from_micros(
            args.get_usize("linger-us").map_err(anyhow::Error::msg)? as u64,
        ),
        queue_capacity: args.get_usize("queue-cap").map_err(anyhow::Error::msg)?,
        device: parse_device(args)?,
        intra_op_threads: args.get_usize("intra-op").map_err(anyhow::Error::msg)?,
        trace_sample: args.get_usize("trace-sample").map_err(anyhow::Error::msg)? as u64,
        chaos: parse_chaos(args)?,
        aot_cache: args.get("aot-cache").map(std::path::PathBuf::from),
    };
    println!(
        "[serve] building {} engine(s) ({}) | {} total worker(s) on {:?} | max-batch {} | queue {}",
        models.len(),
        models.join(", "),
        cfg.total_workers,
        cfg.device,
        cfg.max_batch,
        cfg.queue_capacity
    );
    if cfg.chaos.is_none() {
        if let Ok(spec) = std::env::var(CHAOS_ENV) {
            println!("[serve] {CHAOS_ENV} set: chaos plan '{spec}' (env)");
        }
    }
    let router = Arc::new(ModelRouter::from_zoo(&models, &cfg)?);
    for name in router.models() {
        let e = router.engine(name).expect("registered model");
        println!(
            "[serve]   {name}: {} inputs/sample, {} outputs/sample, {} worker(s)",
            e.sample_len(),
            e.output_len(),
            e.config().workers
        );
    }
    let server = HttpServer::bind(addr, router, HttpConfig::default())?;
    println!("[serve] listening on http://{}", server.local_addr());
    println!(
        "[serve] POST /v1/models/<name>:predict | GET /v1/models | GET /healthz \
         | GET /metrics[?format=prometheus] | GET /admin/trace \
         | POST /admin/models/<name>:publish | POST /admin/shutdown"
    );
    server.wait_shutdown();
    println!("[serve] shutdown requested; draining...");
    server.shutdown();
    println!("[serve] drained clean");
    Ok(())
}

/// Mode 3: closed-loop HTTP load generator against a running server.
fn run_http_client(args: &Args, target: &str) -> anyhow::Result<()> {
    let model = args.get("net").unwrap_or("lenet");
    let requests = args.get_usize("requests").map_err(anyhow::Error::msg)?;
    let clients = args.get_usize("clients").map_err(anyhow::Error::msg)?;

    // Discover the model's input schema from the server's inventory.
    let (status, body) = http_request(target, "GET", "/v1/models", b"")?;
    anyhow::ensure!(status == 200, "GET /v1/models returned {status}");
    let inv = Json::parse(std::str::from_utf8(&body)?).map_err(anyhow::Error::msg)?;
    let sample_len = inv
        .get("models")
        .and_then(|m| m.as_arr())
        .and_then(|arr| {
            arr.iter()
                .find(|m| m.get("name").and_then(|n| n.as_str()) == Some(model))
        })
        .and_then(|m| m.get("sample_len"))
        .and_then(|v| v.as_usize())
        .ok_or_else(|| anyhow::anyhow!("model '{model}' is not served at {target}"))?;

    println!(
        "[serve] HTTP load test against http://{target}: model {model} ({sample_len} inputs/sample), {requests} requests from {clients} client(s)..."
    );
    let report = http_load_test(target, model, sample_len, clients, requests, 0xF_EC_AF_FE)?;
    anyhow::ensure!(
        report.requests > 0,
        "load test completed no requests ({} failed) — is the server healthy?",
        report.failed
    );
    let mut lats = report.latencies_ns.clone();
    let s = summarize("request latency", &mut lats);
    println!(
        "{}",
        report_table(&format!("{model} HTTP serving load test"), &report, &s).render()
    );

    if let Some(path) = args.get("json") {
        let mut o = Json::obj();
        o.set("net", Json::str(model));
        o.set("transport", Json::str("http"));
        o.set("clients", Json::num(clients as f64));
        o.set("requests", Json::num(report.requests as f64));
        o.set("failed", Json::num(report.failed as f64));
        o.set("shed_expired", Json::num(report.shed_expired as f64));
        o.set("breaker_retries", Json::num(report.breaker_retries as f64));
        o.set("rps", Json::num(report.rps));
        o.set("p50_ms", Json::num(s.median_ns / 1e6));
        o.set("p95_ms", Json::num(s.p95_ns / 1e6));
        o.set("p99_ms", Json::num(s.p99_ns / 1e6));
        std::fs::write(path, o.to_pretty())?;
        println!("[serve] wrote {path}");
    }
    Ok(())
}

/// Mode 1: the original in-process closed-loop load test.
fn run_load_test(args: &Args) -> anyhow::Result<()> {
    let name = args.get("net").unwrap_or("lenet");
    let (param, precision) = if std::path::Path::new(name).is_file() {
        let text = std::fs::read_to_string(name)?;
        (fecaffe::proto::parse_net(&text).map_err(anyhow::Error::msg)?, Default::default())
    } else {
        let (base, precision) = fecaffe::quant::split_model_name(name)?;
        (zoo::by_name(base, 1)?, precision)
    };
    let cfg = EngineConfig {
        precision,
        workers: args.get_usize("workers").map_err(anyhow::Error::msg)?,
        max_batch: args.get_usize("max-batch").map_err(anyhow::Error::msg)?,
        max_linger: Duration::from_micros(
            args.get_usize("linger-us").map_err(anyhow::Error::msg)? as u64,
        ),
        queue_capacity: args.get_usize("queue-cap").map_err(anyhow::Error::msg)?,
        device: parse_device(args)?,
        intra_op_threads: args.get_usize("intra-op").map_err(anyhow::Error::msg)?,
        trace_sample: args.get_usize("trace-sample").map_err(anyhow::Error::msg)? as u64,
        chaos: parse_chaos(args)?,
        aot_cache: args.get("aot-cache").map(std::path::PathBuf::from),
        ..EngineConfig::default()
    };
    let requests = args.get_usize("requests").map_err(anyhow::Error::msg)?;
    let clients = args.get_usize("clients").map_err(anyhow::Error::msg)?;

    println!(
        "[serve] {} | {} worker(s) x {} intra-op thread(s) on {:?} | max-batch {} | linger {:?} | queue {}",
        param.name,
        cfg.workers,
        cfg.intra_op_budget(),
        cfg.device,
        cfg.max_batch,
        cfg.max_linger,
        cfg.queue_capacity
    );
    let engine = Engine::new(&param, cfg.clone())?;
    println!(
        "[serve] model ready: {} inputs/sample, {} outputs/sample, {} shared parameters",
        engine.sample_len(),
        engine.output_len(),
        engine.weights().num_parameters()
    );
    println!("[serve] load test: {requests} requests from {clients} client(s)...");

    let report = load_test(&engine, clients, requests, 0xF_EC_AF_FE);
    engine.shutdown();
    let snap = engine.metrics().snapshot();

    anyhow::ensure!(
        report.requests > 0,
        "load test completed no requests ({} failed) — see worker errors above",
        report.failed
    );
    let mut lats = report.latencies_ns.clone();
    let s = summarize("request latency", &mut lats);

    let mut table = report_table(&format!("{} serving load test", param.name), &report, &s);
    table.row(&["batches executed".into(), format!("{}", snap.batches)]);
    table.row(&["mean batch size".into(), format!("{:.2}", snap.mean_batch)]);
    table.row(&["full batches".into(), format!("{}", snap.full_batches)]);
    // Dynamic-shape accounting: rows the reshaped replicas actually
    // executed (bucketed) vs rows that carried requests.
    table.row(&[
        "batch occupancy".into(),
        format!(
            "{:.2} ({} filled / {} executed rows)",
            snap.batch_occupancy, snap.filled_rows, snap.executed_rows
        ),
    ]);
    if snap.sim_batches > 0 {
        // FPGA-sim workers: batch cost in *simulated* device time (the
        // paper's cost model), alongside host wallclock.
        table.row(&["sim time / batch p50".into(), fmt_ns(snap.sim_p50_ns)]);
        table.row(&["sim time / batch p99".into(), fmt_ns(snap.sim_p99_ns)]);
        table.row(&["sim time total".into(), fmt_ns(snap.sim_total_ns as f64)]);
    }
    // Failure breakdown from the engine's own counters: every
    // non-success outcome accounted by kind, plus what the supervision
    // machinery did about the failures.
    table.row(&["worker-failed".into(), format!("{}", snap.failed)]);
    table.row(&["shed-expired".into(), format!("{}", snap.shed_expired)]);
    table.row(&["rejected (queue full)".into(), format!("{}", snap.rejected)]);
    table.row(&["breaker-rejected".into(), format!("{}", snap.breaker_rejected)]);
    if snap.restarts + snap.retries + snap.breaker_trips > 0 {
        table.row(&["worker restarts".into(), format!("{}", snap.restarts)]);
        table.row(&["transient retries".into(), format!("{}", snap.retries)]);
        table.row(&["breaker trips".into(), format!("{}", snap.breaker_trips)]);
    }
    println!("{}", table.render());

    if let Some(path) = args.get("json") {
        let mut o = Json::obj();
        o.set("net", Json::str(param.name.clone()));
        o.set("transport", Json::str("inproc"));
        o.set("workers", Json::num(cfg.workers as f64));
        o.set("max_batch", Json::num(cfg.max_batch as f64));
        o.set("requests", Json::num(report.requests as f64));
        o.set("rps", Json::num(report.rps));
        o.set("p50_ms", Json::num(s.median_ns / 1e6));
        o.set("p95_ms", Json::num(s.p95_ns / 1e6));
        o.set("p99_ms", Json::num(s.p99_ns / 1e6));
        o.set("mean_batch", Json::num(snap.mean_batch));
        o.set("occupancy", Json::num(snap.batch_occupancy));
        o.set("filled_rows", Json::num(snap.filled_rows as f64));
        o.set("executed_rows", Json::num(snap.executed_rows as f64));
        let mut fb = Json::obj();
        fb.set("worker_failed", Json::num(snap.failed as f64));
        fb.set("shed_expired", Json::num(snap.shed_expired as f64));
        fb.set("rejected", Json::num(snap.rejected as f64));
        fb.set("breaker_rejected", Json::num(snap.breaker_rejected as f64));
        o.set("failure_breakdown", fb);
        o.set("restarts", Json::num(snap.restarts as f64));
        o.set("transient_retries", Json::num(snap.retries as f64));
        if snap.sim_batches > 0 {
            o.set("sim_batch_p50_ms", Json::num(snap.sim_p50_ns / 1e6));
            o.set("sim_batch_p99_ms", Json::num(snap.sim_p99_ns / 1e6));
            o.set("sim_total_ms", Json::num(snap.sim_total_ns as f64 / 1e6));
        }
        std::fs::write(path, o.to_pretty())?;
        println!("[serve] wrote {path}");
    }
    Ok(())
}

fn run(args: &Args) -> anyhow::Result<()> {
    if let Some(target) = args.get("target") {
        let target = target.to_string();
        return run_http_client(args, &target);
    }
    if let Some(addr) = args.get("http") {
        let addr = addr.to_string();
        return run_http_server(args, &addr);
    }
    run_load_test(args)
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv, SPECS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!(
                "error: {e}\n\n{}",
                usage("serve", "Batched multi-worker inference serving engine", SPECS)
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
