//! Multi-model routing: one admission layer over N engines.
//!
//! A [`ModelRouter`] owns an [`Engine`] per model (zoo nets pass
//! through [`crate::zoo::deploy`] inside `Engine::new`), splitting one
//! shared worker/intra-op thread budget across them so M engines × N
//! workers never oversubscribe the machine — the same per-model
//! dispatch unit Caffeinated FPGAs uses for layer routing. Admission
//! stays per model: each engine keeps its own bounded queue, so one
//! overloaded model returns `Overloaded` without starving the others.

use super::engine::{
    DeviceKind, Engine, EngineConfig, PublishError, ResponseHandle, ServeError,
};
use super::lock_unpoisoned;
use super::metrics::{prometheus_text, MetricsReport};
use crate::net::WeightSnapshot;
use crate::obs::{LayerAgg, TrainMetrics};
use crate::util::chaos::FaultPlan;
use crate::util::json::Json;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Budget shared by every model the router serves; each engine gets an
/// even slice (see [`ModelRouter::from_zoo`]).
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Total worker threads across all models (at least one per model).
    pub total_workers: usize,
    /// Micro-batch upper bound, per model.
    pub max_batch: usize,
    /// Micro-batch linger deadline, per model.
    pub max_linger: Duration,
    /// Admission queue capacity, per model.
    pub queue_capacity: usize,
    pub device: DeviceKind,
    /// Intra-op threads per worker; 0 = split the process thread budget
    /// over every worker of every engine (an engine's own auto-split
    /// only knows its workers, not its siblings').
    pub intra_op_threads: usize,
    /// Batch-trace sampling (per model): trace one batch in every N
    /// executed; 0 = off. See [`EngineConfig::trace_sample`].
    pub trace_sample: u64,
    /// Fault-injection plan shared by every model's engine (each engine
    /// gets its own deterministic `ChaosState` seeded from the same
    /// plan). `None` falls back to `FECAFFE_CHAOS`; see
    /// [`EngineConfig::chaos`].
    pub chaos: Option<FaultPlan>,
    /// AOT plan-cache directory shared by every model's engine. `None`
    /// falls back to `FECAFFE_AOT_CACHE`; see [`EngineConfig::aot_cache`].
    pub aot_cache: Option<std::path::PathBuf>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            total_workers: 4,
            max_batch: 8,
            max_linger: Duration::from_millis(2),
            queue_capacity: 256,
            device: DeviceKind::Cpu,
            intra_op_threads: 0,
            trace_sample: 0,
            chaos: None,
            aot_cache: None,
        }
    }
}

/// Why the router refused a submission (or a weight publish).
#[derive(Debug, Clone, PartialEq)]
pub enum RouteError {
    /// No engine registered under that name.
    UnknownModel(String),
    /// The model's engine refused (overload, shutdown, bad sample).
    Serve(ServeError),
    /// The model's engine refused a weight publish (schema mismatch or
    /// stale version).
    Publish(PublishError),
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            RouteError::Serve(e) => write!(f, "{e}"),
            RouteError::Publish(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for RouteError {}

/// N serving engines behind one name-keyed admission surface.
pub struct ModelRouter {
    engines: Vec<(String, Engine)>,
    /// Training metrics attached by `train --serve` (the live solver
    /// publishing into this router), surfaced through `/metrics`.
    training: Mutex<Option<Arc<TrainMetrics>>>,
}

impl ModelRouter {
    /// Build one engine per zoo model name, splitting `cfg`'s worker
    /// and intra-op budgets evenly across them. Every model passes the
    /// `netlint` admission gate inside [`Engine::new`]: a net with
    /// error-severity findings makes the whole router construction fail
    /// with a [`crate::netlint::LintError`] in the chain (naming the
    /// model), so a misconfigured net can never start serving.
    ///
    /// Model names may carry a precision suffix (`lenet@int8`,
    /// `vgg16@fp16`): the zoo is looked up by the base name, the engine
    /// serves at the suffixed precision, and the model is registered —
    /// routed, health-checked, metered — under the *full* name, so
    /// `lenet` and `lenet@int8` serve side by side from one process.
    pub fn from_zoo(models: &[&str], cfg: &RouterConfig) -> anyhow::Result<ModelRouter> {
        anyhow::ensure!(!models.is_empty(), "router needs at least one model");
        let mut seen = std::collections::BTreeSet::new();
        let mut parsed = Vec::with_capacity(models.len());
        for m in models {
            anyhow::ensure!(seen.insert(*m), "duplicate model '{m}'");
            parsed.push(crate::quant::split_model_name(m)?);
        }
        let (workers_per_model, intra_op) = split_budget(
            cfg.total_workers,
            models.len(),
            cfg.intra_op_threads,
        );
        let mut engines = Vec::with_capacity(models.len());
        for ((name, (base, precision)), &workers) in
            models.iter().zip(parsed).zip(&workers_per_model)
        {
            let param = crate::zoo::by_name(base, 1)?;
            let ecfg = EngineConfig {
                workers,
                max_batch: cfg.max_batch,
                max_linger: cfg.max_linger,
                queue_capacity: cfg.queue_capacity,
                device: cfg.device,
                intra_op_threads: intra_op,
                trace_sample: cfg.trace_sample,
                chaos: cfg.chaos.clone(),
                aot_cache: cfg.aot_cache.clone(),
                precision,
                ..EngineConfig::default()
            };
            let engine = Engine::new(&param, ecfg)
                .map_err(|e| e.context(format!("building engine for model '{name}'")))?;
            engines.push((name.to_string(), engine));
        }
        Ok(ModelRouter { engines, training: Mutex::new(None) })
    }

    /// Wrap pre-built engines (custom prototxt models, tests). The
    /// caller owns the budget split in this case.
    pub fn from_engines(engines: Vec<(String, Engine)>) -> anyhow::Result<ModelRouter> {
        anyhow::ensure!(!engines.is_empty(), "router needs at least one engine");
        let mut seen = std::collections::BTreeSet::new();
        for (name, _) in &engines {
            anyhow::ensure!(seen.insert(name.clone()), "duplicate model '{name}'");
        }
        Ok(ModelRouter { engines, training: Mutex::new(None) })
    }

    /// Attach the metrics of a live training run (`train --serve`), so
    /// `/metrics` reports solver-side iteration timing and loss next to
    /// the serving counters.
    pub fn attach_training(&self, metrics: Arc<TrainMetrics>) {
        *lock_unpoisoned(&self.training) = Some(metrics);
    }

    pub fn engine(&self, model: &str) -> Option<&Engine> {
        self.engines.iter().find(|(n, _)| n == model).map(|(_, e)| e)
    }

    /// Registered model names, in registration order.
    pub fn models(&self) -> Vec<&str> {
        self.engines.iter().map(|(n, _)| n.as_str()).collect()
    }

    /// Route one sample to `model`'s engine (admission-controlled,
    /// non-blocking — `Serve(Overloaded)` means back off and retry).
    pub fn submit(&self, model: &str, sample: Vec<f32>) -> Result<ResponseHandle, RouteError> {
        self.submit_with_deadline(model, sample, None)
    }

    /// [`ModelRouter::submit`] with a per-request latency budget —
    /// requests still queued when it expires are shed as
    /// `DeadlineExceeded` (HTTP 504) instead of occupying a batch slot.
    pub fn submit_with_deadline(
        &self,
        model: &str,
        sample: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<ResponseHandle, RouteError> {
        let engine = self
            .engine(model)
            .ok_or_else(|| RouteError::UnknownModel(model.to_string()))?;
        engine.submit_with_deadline(sample, deadline).map_err(RouteError::Serve)
    }

    /// Hot-swap `model`'s weights: validate + atomically publish `snap`
    /// into its engine (`POST /admin/models/<name>:publish`). Workers
    /// adopt at their next batch boundary; in-flight requests are
    /// untouched. Returns the published version.
    pub fn publish(&self, model: &str, snap: WeightSnapshot) -> Result<u64, RouteError> {
        let engine = self
            .engine(model)
            .ok_or_else(|| RouteError::UnknownModel(model.to_string()))?;
        engine.publish_weights(snap).map_err(RouteError::Publish)
    }

    /// Per-model metrics snapshots as one JSON object (`GET /metrics`),
    /// plus a `training` section when a live solver is attached.
    pub fn metrics_json(&self) -> Json {
        let mut o = Json::obj();
        for (name, engine) in &self.engines {
            o.set(name, engine.metrics().snapshot().to_json());
        }
        if let Some(t) = lock_unpoisoned(&self.training).as_ref() {
            o.set("training", t.to_json());
        }
        o
    }

    /// Everything `/metrics` knows, in the Prometheus text exposition
    /// format (`GET /metrics?format=prometheus`): per-model serving
    /// families (exact histogram buckets — see
    /// [`super::metrics::prometheus_text`]), per-layer timing gauges
    /// from sampled batches, and training families when attached.
    pub fn metrics_prometheus(&self) -> String {
        let reports: Vec<(String, String, MetricsReport)> = self
            .engines
            .iter()
            .map(|(n, e)| {
                (base_name(n).to_string(), e.precision().label().to_string(), e.metrics().snapshot())
            })
            .collect();
        let mut out = prometheus_text(&reports);
        let mut layer_rows = Vec::new();
        for (name, engine) in &self.engines {
            let precision = engine.precision().label();
            for (layer, agg) in engine.obs().layers.snapshot() {
                layer_rows.push((base_name(name).to_string(), precision, layer, agg));
            }
        }
        if !layer_rows.is_empty() {
            let families: &[(&str, fn(&LayerAgg) -> f64)] = &[
                ("fecaffe_layer_batches_total", |a| a.batches as f64),
                ("fecaffe_layer_forward_seconds_total", |a| a.wall_ns as f64 / 1e9),
                ("fecaffe_layer_sim_seconds_total", |a| a.sim_ns as f64 / 1e9),
            ];
            for &(name, get) in families {
                out.push_str(&format!("# TYPE {name} counter\n"));
                for (model, precision, layer, agg) in &layer_rows {
                    out.push_str(&format!(
                        "{name}{{model=\"{model}\",precision=\"{precision}\",layer=\"{layer}\"}} {}\n",
                        get(agg)
                    ));
                }
            }
        }
        if let Some(t) = lock_unpoisoned(&self.training).as_ref() {
            t.render_prometheus(&mut out);
        }
        out
    }

    /// Every sampled batch trace across every model, merged into one
    /// chrome-trace JSON document — one named process group per batch
    /// (`GET /admin/trace`). `clear` drains the rings afterwards.
    pub fn traces_chrome_json(&self, clear: bool) -> String {
        let mut batches = Vec::new();
        for (name, engine) in &self.engines {
            for t in engine.obs().traces.dump() {
                let label = format!(
                    "{name} batch {} ({}/{} rows, weights v{})",
                    t.seq, t.filled, t.rows, t.weights_version
                );
                batches.push((label, t.spans));
            }
            if clear {
                engine.obs().traces.clear();
            }
        }
        crate::trace::chrome_trace_batches(&batches)
    }

    /// Liveness + readiness detail for `GET /healthz`: per-model weight
    /// versions, worker health, breaker state and queue depth. Three
    /// status tiers so load balancers can act *before* total
    /// exhaustion: `ok` (every model at full worker strength, all
    /// breakers closed), `degraded` (some model below its configured
    /// worker count, or a breaker open/half-open, but every model can
    /// still serve), `unhealthy` (some model has zero workers left).
    /// The overall status is the worst model's.
    pub fn health_json(&self, uptime_s: f64) -> Json {
        let mut models = Vec::new();
        // 0 = ok, 1 = degraded, 2 = unhealthy; overall is the max.
        let mut worst = 0usize;
        for (name, engine) in &self.engines {
            let healthy = engine.healthy_workers();
            let configured = engine.config().workers;
            let breaker = engine.breaker_state();
            let tier = if healthy == 0 {
                2
            } else if healthy < configured || breaker != "closed" {
                1
            } else {
                0
            };
            worst = worst.max(tier);
            let mut m = Json::obj();
            m.set("name", Json::str(name.clone()));
            m.set("precision", Json::str(engine.precision().label()));
            m.set("status", Json::str(["ok", "degraded", "unhealthy"][tier]));
            m.set("weights_version", Json::num(engine.weights_version() as f64));
            m.set("workers", Json::num(configured as f64));
            m.set("healthy_workers", Json::num(healthy as f64));
            m.set("breaker", Json::str(breaker));
            m.set(
                "restarts",
                Json::num(engine.metrics().restarts.load(std::sync::atomic::Ordering::Relaxed)
                    as f64),
            );
            m.set("queue_depth", Json::num(engine.queue_depth() as f64));
            models.push(m);
        }
        let mut o = Json::obj();
        o.set("status", Json::str(["ok", "degraded", "unhealthy"][worst]));
        o.set("uptime_s", Json::num(uptime_s));
        o.set("models", Json::Arr(models));
        o
    }

    /// Model inventory with input/output schema (`GET /v1/models`).
    pub fn models_json(&self) -> Json {
        let mut arr = Vec::new();
        for (name, engine) in &self.engines {
            let mut m = Json::obj();
            m.set("name", Json::str(name.clone()));
            m.set("precision", Json::str(engine.precision().label()));
            m.set("sample_len", Json::num(engine.sample_len() as f64));
            m.set("output_len", Json::num(engine.output_len() as f64));
            m.set("max_batch", Json::num(engine.config().max_batch as f64));
            m.set("workers", Json::num(engine.config().workers as f64));
            m.set("weights_version", Json::num(engine.weights_version() as f64));
            arr.push(m);
        }
        let mut o = Json::obj();
        o.set("models", Json::Arr(arr));
        o
    }

    /// Gracefully shut every engine down (stop admissions, drain, join
    /// workers). Idempotent — `Engine::shutdown` is.
    pub fn shutdown(&self) {
        for (_, engine) in &self.engines {
            engine.shutdown();
        }
    }
}

/// Base zoo name of a registered model: the part before any `@precision`
/// suffix (metrics label the base and carry precision separately).
fn base_name(registered: &str) -> &str {
    registered.split_once('@').map_or(registered, |(b, _)| b)
}

/// Split of the shared budget: `total_workers` across `models` engines
/// (≥1 each, the first `total % models` engines absorbing the
/// remainder so no requested worker is silently dropped), and the
/// process intra-op thread budget across *all* resulting workers
/// unless the caller pinned it.
fn split_budget(
    total_workers: usize,
    models: usize,
    intra_op: usize,
) -> (Vec<usize>, usize) {
    let models = models.max(1);
    let base = total_workers / models;
    let extra = total_workers % models;
    let per: Vec<usize> = (0..models)
        .map(|i| (base + usize::from(i < extra)).max(1))
        .collect();
    let all_workers: usize = per.iter().sum();
    let intra = if intra_op > 0 {
        intra_op
    } else {
        (crate::util::pool::default_threads() / all_workers.max(1)).max(1)
    };
    (per, intra)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_splits_with_remainder_and_a_floor_of_one() {
        assert_eq!(split_budget(8, 2, 1), (vec![4, 4], 1));
        // The remainder is distributed, not dropped: 5 workers over 2
        // models is 3+2, and 4 over 3 is 2+1+1.
        assert_eq!(split_budget(5, 2, 1), (vec![3, 2], 1));
        assert_eq!(split_budget(4, 3, 1), (vec![2, 1, 1], 1));
        // More models than workers: every model still gets one worker.
        assert_eq!(split_budget(1, 5, 2), (vec![1, 1, 1, 1, 1], 2));
        // Auto intra-op divides the machine by total workers, never 0.
        let (w, i) = split_budget(4, 2, 0);
        assert_eq!(w, vec![2, 2]);
        assert!(i >= 1);
    }

    #[test]
    fn from_zoo_rejects_bad_model_lists() {
        let cfg = RouterConfig::default();
        assert!(ModelRouter::from_zoo(&[], &cfg).is_err());
        // Duplicates and unknown names fail before any engine is built.
        assert!(ModelRouter::from_zoo(&["lenet", "lenet"], &cfg).is_err());
        assert!(ModelRouter::from_zoo(&["resnet"], &cfg).is_err());
        // Precision suffixes are validated before any engine is built.
        assert!(ModelRouter::from_zoo(&["lenet@int4"], &cfg).is_err());
        assert!(ModelRouter::from_zoo(&["@int8"], &cfg).is_err());
    }

    #[test]
    fn base_name_strips_precision_suffix() {
        assert_eq!(base_name("lenet"), "lenet");
        assert_eq!(base_name("lenet@int8"), "lenet");
        assert_eq!(base_name("vgg16@fp16"), "vgg16");
    }

    #[test]
    fn admission_refuses_error_severity_net() {
        // A dangling bottom on the score path survives `zoo::deploy`'s
        // dead-branch pruning, so the engine's netlint gate must refuse
        // the model before any worker starts.
        let text = r#"
name: "broken"
layer { name: "data" type: "SyntheticData" top: "data" top: "label"
        data_param { batch_size: 2 channels: 1 height: 8 width: 8 num_classes: 3 source: "digits" } }
layer { name: "fc" type: "InnerProduct" bottom: "missing" top: "fc"
        inner_product_param { num_output: 3 weight_filler { type: "xavier" } } }
layer { name: "loss" type: "SoftmaxWithLoss" bottom: "fc" bottom: "label" top: "loss" }
"#;
        let param = crate::proto::parse_net(text).unwrap();
        let err = Engine::new(&param, EngineConfig::default())
            .err()
            .expect("broken net must be refused at admission");
        let msg = format!("{err:#}");
        assert!(msg.contains("NL0001"), "error names the NL code: {msg}");
        assert!(
            err.chain()
                .any(|c| c.downcast_ref::<crate::netlint::LintError>().is_some()),
            "chain carries a typed LintError: {msg}"
        );
    }

    #[test]
    fn route_error_display_names_the_model() {
        let e = RouteError::UnknownModel("squeezenet".into());
        assert!(e.to_string().contains("squeezenet"));
        let e = RouteError::Serve(ServeError::ShuttingDown);
        assert!(e.to_string().contains("shutting down"));
    }
}
