//! Integration over the PJRT runtime: artifact execution must match the
//! native math bit-for-bit (within fp tolerance) on every kernel family,
//! and a whole net forward on the artifact-backed FPGA device must match
//! the CPU device. Skips (with a notice) when `make artifacts` hasn't run.

use fecaffe::device::cpu::CpuDevice;
use fecaffe::device::fpga::FpgaSimDevice;
use fecaffe::device::{Device, Kernel, KernelCall};
use fecaffe::math::{ConvGeom, PoolGeom};
use fecaffe::net::Net;
use fecaffe::proto::Phase;
use fecaffe::runtime::PjrtBackend;
use fecaffe::util::prng::Pcg32;
use fecaffe::zoo;

fn backend() -> Option<PjrtBackend> {
    let b = PjrtBackend::auto();
    if b.is_none() {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
    }
    b
}

/// Run one call on both devices with identical inputs; compare outputs.
fn check_kernel(kernel: Kernel, in_lens: &[usize], out_lens: &[usize], tol: f32) {
    let Some(backend) = backend() else { return };
    let mut fpga = FpgaSimDevice::new().with_backend(Box::new(backend));
    let mut cpu = CpuDevice::new();
    let mut rng = Pcg32::new(0xA07_u64);
    let mut data: Vec<Vec<f32>> = Vec::new();
    for &n in in_lens.iter().chain(out_lens.iter()) {
        let mut v = vec![0f32; n];
        rng.fill_uniform(&mut v, -1.0, 1.0);
        data.push(v);
    }
    let run = |dev: &mut dyn Device| -> Vec<Vec<f32>> {
        let mut ids = Vec::new();
        for v in &data {
            let id = dev.alloc(v.len()).unwrap();
            dev.write(id, v);
            ids.push(id);
        }
        let (ins, outs) = ids.split_at(in_lens.len());
        dev.launch(&KernelCall::new(kernel.clone(), ins, outs)).unwrap();
        outs.iter()
            .zip(out_lens.iter())
            .map(|(&id, &n)| {
                let mut out = vec![0f32; n];
                dev.read(id, &mut out);
                out
            })
            .collect()
    };
    let got_f = run(&mut fpga);
    let got_c = run(&mut cpu);
    assert!(fpga.profiler.artifact_launches > 0, "{kernel:?} did not use the artifact");
    for (i, (a, b)) in got_f.iter().zip(got_c.iter()).enumerate() {
        fecaffe::util::tcheck::close(a, b, tol, tol)
            .unwrap_or_else(|e| panic!("{kernel:?} output {i}: {e}"));
    }
}

#[test]
fn pjrt_gemm_matches_native() {
    // lenet conv1 forward shape (in the manifest for sure)
    check_kernel(
        Kernel::GemmNN { m: 20, n: 576, k: 25, alpha: 1.0, beta: 0.0 },
        &[20 * 25, 25 * 576],
        &[20 * 576],
        1e-4,
    );
}

#[test]
fn pjrt_gemm_acc_matches_native() {
    // lenet conv1 weight-grad (GemmNT beta=1)
    check_kernel(
        Kernel::GemmNT { m: 20, n: 25, k: 576, alpha: 1.0, beta: 1.0 },
        &[20 * 576, 25 * 576],
        &[20 * 25],
        1e-3,
    );
}

#[test]
fn pjrt_relu_bucketed_matches_native() {
    // n=300 pads into the 512 bucket
    check_kernel(Kernel::ReluF { n: 300, slope: 0.0 }, &[300], &[300], 0.0);
}

#[test]
fn pjrt_im2col_matches_native() {
    let geom = ConvGeom {
        channels: 1, height: 28, width: 28,
        kernel_h: 5, kernel_w: 5, pad_h: 0, pad_w: 0, stride_h: 1, stride_w: 1,
    };
    check_kernel(
        Kernel::Im2col { geom },
        &[geom.im_len()],
        &[geom.col_len()],
        0.0,
    );
}

#[test]
fn pjrt_maxpool_matches_native_including_mask() {
    let geom = PoolGeom {
        channels: 20, height: 24, width: 24,
        kernel_h: 2, kernel_w: 2, pad_h: 0, pad_w: 0, stride_h: 2, stride_w: 2,
    };
    check_kernel(
        Kernel::MaxPoolF { geom, num: 1 },
        &[geom.in_len()],
        &[geom.out_len(), geom.out_len()],
        0.0,
    );
}

#[test]
fn pjrt_sgd_update_matches_native() {
    let n = 510; // pads into 512 bucket
    check_kernel(
        Kernel::SgdUpdate { n, lr: 0.05, momentum: 0.9 },
        &[n],
        &[n, n],
        1e-5,
    );
}

#[test]
fn lenet_forward_identical_on_pjrt_and_cpu() {
    let Some(backend) = backend() else { return };
    let param = zoo::by_name("lenet", 2).unwrap();
    let mut cpu = CpuDevice::new();
    let mut net_c = Net::from_param(&param, Phase::Train, &mut cpu).unwrap();
    let loss_c = net_c.forward_backward(&mut cpu).unwrap();

    let mut fpga = FpgaSimDevice::new().with_backend(Box::new(backend));
    let mut net_f = Net::from_param(&param, Phase::Train, &mut fpga).unwrap();
    let loss_f = net_f.forward_backward(&mut fpga).unwrap();
    assert!(
        fpga.profiler.artifact_launches > fpga.profiler.native_launches,
        "most launches should ride artifacts: {} vs {}",
        fpga.profiler.artifact_launches,
        fpga.profiler.native_launches
    );
    assert!(
        (loss_c - loss_f).abs() < 1e-3,
        "loss mismatch: cpu {loss_c} vs pjrt {loss_f}"
    );
    // conv1 gradients agree
    let gc = net_c.params()[0].blob.borrow_mut().diff_vec(&mut cpu);
    let gf = net_f.params()[0].blob.borrow_mut().diff_vec(&mut fpga);
    fecaffe::util::tcheck::close(&gf, &gc, 1e-3, 1e-3).unwrap();
}

#[test]
fn artifact_miss_falls_back_to_native() {
    let Some(backend) = backend() else { return };
    let mut fpga = FpgaSimDevice::new().with_backend(Box::new(backend));
    // A gemm shape no zoo net uses → miss → native fallback, same result.
    let (m, n, k) = (7usize, 13, 11);
    let a = fpga.alloc(m * k).unwrap();
    let b = fpga.alloc(k * n).unwrap();
    let c = fpga.alloc(m * n).unwrap();
    fpga.write(a, &vec![0.5; m * k]);
    fpga.write(b, &vec![2.0; k * n]);
    fpga.launch(&KernelCall::new(
        Kernel::GemmNN { m, n, k, alpha: 1.0, beta: 0.0 },
        &[a, b],
        &[c],
    ))
    .unwrap();
    assert_eq!(fpga.profiler.native_launches, 1);
    let mut out = vec![0f32; m * n];
    fpga.read(c, &mut out);
    assert!((out[0] - 11.0).abs() < 1e-4);
}
