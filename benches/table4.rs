//! E4 — regenerate paper Table 4: the comparison with F-CNN and FPDeep.
//!
//! * feature matrix (framework, solvers, expansibility — static),
//! * LeNet L1–L6 forward/backward at batch 384 vs the F-CNN model, with
//!   the headline average-execution-time improvement factors,
//! * ImageNet epoch-time projections (AlexNet bs32 SGD, SqueezeNet bs16
//!   SGD, GoogLeNet bs16 Adam) from one simulated solver iteration,
//! * the VGG-16 training out-of-memory reproduction (2 GB board DDR).

use fecaffe::baseline::fcnn;
use fecaffe::baseline::fpdeep::FpdeepCluster;
use fecaffe::bench_tables::timing_device;
use fecaffe::data::imagenet::IMAGENET_TRAIN_IMAGES;
use fecaffe::device::Device;
use fecaffe::net::Net;
use fecaffe::proto::Phase;
use fecaffe::solver::Solver;
use fecaffe::util::table::{ms, ratio, Table};
use fecaffe::zoo;

/// LeNet per-paper-row times on the simulated board at batch `b`.
fn fecaffe_lenet_rows(batch: usize) -> anyhow::Result<Vec<(String, f64, f64)>> {
    let mut dev = timing_device();
    let rows = fecaffe::bench_tables::grouped_layer_times("lenet", batch, &mut dev)?;
    // Map zoo layer groups to the paper's L1..L6 labels.
    let label = |g: &str| match g {
        "conv1" => Some("L1 (Conv)"),
        "pool1" => Some("L2 (Pool)"),
        "conv2" => Some("L3 (Conv)"),
        "pool2" => Some("L4 (Pool)"),
        "ip1" | "relu1" => Some("L5 (FC)"),
        "ip2" => Some("L6 (FC)"),
        _ => None,
    };
    let mut out: Vec<(String, f64, f64)> = Vec::new();
    for (g, f, b) in rows {
        if let Some(l) = label(&g) {
            if let Some(last) = out.last_mut() {
                if last.0 == l {
                    last.1 += f;
                    last.2 += b;
                    continue;
                }
            }
            out.push((l.to_string(), f, b));
        }
    }
    Ok(out)
}

fn epoch_hours(name: &str, batch: usize) -> anyhow::Result<f64> {
    let mut dev = timing_device();
    let param = zoo::by_name(name, batch)?;
    let net = Net::from_param(&param, Phase::Train, &mut dev)?;
    let sp = zoo::default_solver(name)?;
    let mut solver = Solver::new(sp, net, &mut dev)?;
    solver.step(&mut dev)?; // warm allocations
    dev.reset_timing();
    solver.step(&mut dev)?;
    dev.synchronize();
    let per_iter_s = dev.sim_clock_ns().unwrap() as f64 / 1e9;
    let iters = (IMAGENET_TRAIN_IMAGES as f64 / batch as f64).ceil();
    Ok(per_iter_s * iters / 3600.0)
}

fn main() -> anyhow::Result<()> {
    // --- feature matrix (paper Table 4, top half) ---
    let mut feat = Table::new(
        "Table 4 — feature comparison",
        &["", "Our Work (FeCaffe repro)", "FCNN [8]", "FPDeep [9]"],
    );
    feat.row_strs(&["Framework", "Caffe (workalike)", "Customized", "Customized"]);
    feat.row_strs(&[
        "Develop Tool",
        "JAX/Pallas AOT + PJRT (OpenCL-AOC analogue)",
        "MaxCompiler",
        "RTL Generator",
    ]);
    feat.row_strs(&[
        "CNN Feature",
        "Training and Inference",
        "Training and Inference",
        "Training and Inference",
    ]);
    feat.row_strs(&[
        "Networks",
        "AlexNet, VGG, SqueezeNet, GoogLeNet, LeNet (+same-primitive nets)",
        "LeNet",
        "AlexNet, VGG-16/19",
    ]);
    feat.row_strs(&[
        "Solvers",
        "SGD, Nesterov, AdaGrad, RMSProp, AdaDelta, Adam",
        "SGD only",
        "SGD only",
    ]);
    feat.row_strs(&[
        "Hyperparameters",
        "base_lr, lr_policy, gamma, momentum, weight_decay, ... (same as GPU/CPU)",
        "Unknown",
        "Unknown",
    ]);
    feat.row_strs(&["Data Type", "FP32", "FP32", "Fixed-16"]);
    feat.row_strs(&["Boards", "1x S10 (simulated)", "2x Stratix V", "15x VC709"]);
    println!("{}", feat.render());

    // --- LeNet L1-L6 comparison, batch 384 (paper's setting) ---
    let batch = 384;
    let ours = fecaffe_lenet_rows(batch)?;
    let machine = fcnn::FcnnMachine::default();
    let theirs: Vec<(String, f64, f64)> = fcnn::lenet_layers()
        .iter()
        .map(|(n, w)| {
            (
                n.to_string(),
                machine.forward_s(*w, batch) * 1e3,
                machine.backward_s(*w, batch) * 1e3,
            )
        })
        .collect();
    let mut t = Table::new(
        &format!("Table 4 — LeNet L1-L6 (ms, batch={batch})"),
        &[
            "Layer",
            "Ours Fwd",
            "Ours Bwd",
            "FCNN Fwd (model)",
            "FCNN Bwd (model)",
            "FCNN Fwd (publ.)",
            "FCNN Bwd (publ.)",
        ],
    );
    let (mut of, mut ob, mut ff, mut fb) = (0.0, 0.0, 0.0, 0.0);
    for (i, (name, f, b)) in ours.iter().enumerate() {
        let (tf, tb) = (theirs[i].1, theirs[i].2);
        t.row(&[
            name.clone(),
            ms(*f),
            ms(*b),
            ms(tf),
            ms(tb),
            ms(fcnn::PUBLISHED_FWD_MS[i]),
            ms(fcnn::PUBLISHED_BWD_MS[i]),
        ]);
        of += f;
        ob += b;
        ff += tf;
        fb += tb;
    }
    t.row(&[
        "Total".into(),
        format!("{} ({})", ms(of), ratio(ff / of)),
        format!("{} ({})", ms(ob), ratio(fb / ob)),
        ms(ff),
        ms(fb),
        "7060".into(),
        "14300".into(),
    ]);
    println!("{}", t.render());
    println!(
        "Headline: {:.1}x forward / {:.1}x backward average execution-time improvement\n\
         (paper claims 6.4x / 8.4x vs FCNN under the same conditions;\n\
          paper's own numbers: fwd 1102.162 ms, bwd 1710.090 ms)\n",
        ff / of,
        fb / ob
    );

    // --- epoch projections ---
    let mut e = Table::new(
        "Table 4 — ImageNet (1.28M images) epoch projections",
        &["Network", "Batch", "Solver", "Hours/epoch (sim)", "Paper"],
    );
    for (name, batch, paper) in [
        ("alexnet", 32usize, "86.41 h (BS:32, SGD)"),
        ("squeezenet", 16, "(BS:16, SGD; value in paper table)"),
        ("googlenet", 16, "291.08 h (BS:16, Adam)"),
    ] {
        let solver = zoo::default_solver(name)?.kind.ident().to_string();
        let h = epoch_hours(name, batch)?;
        e.row(&[
            name.into(),
            batch.to_string(),
            solver,
            format!("{h:.2}"),
            paper.into(),
        ]);
    }
    // FPDeep comparator row.
    let cluster = FpdeepCluster::default();
    e.row(&[
        "alexnet (FPDeep model)".into(),
        "-".into(),
        "SGD fixp16".into(),
        format!("{:.2}", cluster.epoch_hours(0.72e9, IMAGENET_TRAIN_IMAGES)),
        "0.17 h".into(),
    ]);
    println!("{}", e.render());

    // --- VGG-16 training does not fit the 2 GB board ---
    // (batch 4 — the smallest batch anyone would train at; batch-1 F->B
    // alone fits, which is why Table 1 has VGG numbers.)
    let param = zoo::by_name("vgg16", 4)?;
    // The OOM is the expected outcome — keep its panic backtrace out of
    // the bench output.
    let prev_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let result = std::panic::catch_unwind(|| {
        let mut dev = timing_device(); // true 2 GB capacity
        Net::from_param(&param, Phase::Train, &mut dev)
            .and_then(|net| Solver::new(zoo::default_solver("vgg16")?, net, &mut dev))
            .and_then(|mut s| s.step(&mut dev).map(|_| ()))
    });
    std::panic::set_hook(prev_hook);
    match result {
        Err(_) | Ok(Err(_)) => println!(
            "VGG-16 training on the 2 GB board: NOT PERFORMED — FPGA DDR exhausted\n\
             (paper: \"training of VGG-16 and VGG-19 cannot be performed\")",
        ),
        Ok(Ok(())) => println!("VGG-16 training unexpectedly fit — check DDR model!"),
    }
    Ok(())
}
