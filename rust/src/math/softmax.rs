//! Softmax + SoftmaxWithLoss (paper kernels `Softmax`,
//! `SoftmaxLoss_F/B`), matching Caffe's numerically-stable formulation.

/// Row-wise softmax over an (n, c) matrix.
pub fn softmax_forward(bottom: &[f32], top: &mut [f32], n: usize, c: usize) {
    assert!(bottom.len() >= n * c && top.len() >= n * c);
    for i in 0..n {
        let row = &bottom[i * c..(i + 1) * c];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let out = &mut top[i * c..(i + 1) * c];
        let mut sum = 0.0f32;
        for (o, &v) in out.iter_mut().zip(row.iter()) {
            *o = (v - max).exp();
            sum += *o;
        }
        for o in out.iter_mut() {
            *o /= sum;
        }
    }
}

/// Multinomial logistic loss of softmax probabilities against integer
/// labels (stored as f32, Caffe-style). Returns mean NLL over the batch.
pub fn softmax_loss_forward(prob: &[f32], labels: &[f32], n: usize, c: usize) -> f32 {
    assert!(prob.len() >= n * c && labels.len() >= n);
    let mut loss = 0.0f32;
    for i in 0..n {
        let label = labels[i] as usize;
        assert!(label < c, "label {label} out of range (c={c})");
        loss -= prob[i * c + label].max(f32::MIN_POSITIVE).ln();
    }
    loss / n as f32
}

/// d loss / d logits = (prob - onehot(label)) * loss_weight / n.
pub fn softmax_loss_backward(
    prob: &[f32],
    labels: &[f32],
    bottom_diff: &mut [f32],
    n: usize,
    c: usize,
    loss_weight: f32,
) {
    assert!(prob.len() >= n * c && bottom_diff.len() >= n * c && labels.len() >= n);
    let scale = loss_weight / n as f32;
    for i in 0..n {
        let label = labels[i] as usize;
        for j in 0..c {
            let idx = i * c + j;
            let indicator = if j == label { 1.0 } else { 0.0 };
            bottom_diff[idx] = (prob[idx] - indicator) * scale;
        }
    }
}

/// Top-k accuracy (the Accuracy layer's math).
pub fn accuracy(scores: &[f32], labels: &[f32], n: usize, c: usize, top_k: usize) -> f32 {
    let mut correct = 0usize;
    for i in 0..n {
        let row = &scores[i * c..(i + 1) * c];
        let label = labels[i] as usize;
        let target = row[label];
        // count strictly-greater scores; ties resolve optimistically like
        // Caffe's partial_sort ordering by index
        let rank = row.iter().filter(|&&v| v > target).count();
        if rank < top_k {
            correct += 1;
        }
    }
    correct as f32 / n as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tcheck;

    #[test]
    fn softmax_rows_sum_to_one() {
        let bottom = [1.0, 2.0, 3.0, -1.0, 0.0, 1.0];
        let mut top = [0.0; 6];
        softmax_forward(&bottom, &mut top, 2, 3);
        for i in 0..2 {
            let s: f32 = top[i * 3..(i + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        // monotonicity preserved
        assert!(top[0] < top[1] && top[1] < top[2]);
    }

    #[test]
    fn softmax_is_shift_invariant_and_stable() {
        let a = [1000.0, 1001.0, 1002.0];
        let b = [0.0, 1.0, 2.0];
        let mut ta = [0.0; 3];
        let mut tb = [0.0; 3];
        softmax_forward(&a, &mut ta, 1, 3);
        softmax_forward(&b, &mut tb, 1, 3);
        tcheck::close(&ta, &tb, 1e-6, 0.0).unwrap();
        assert!(ta.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn loss_of_perfect_prediction_is_zero() {
        let prob = [1.0, 0.0, 0.0, 1.0]; // 2 samples, 2 classes
        let labels = [0.0, 1.0];
        let l = softmax_loss_forward(&prob, &labels, 2, 2);
        assert!(l.abs() < 1e-6);
    }

    #[test]
    fn loss_of_uniform_prediction_is_log_c() {
        let c = 4;
        let prob = vec![0.25; c];
        let l = softmax_loss_forward(&prob, &[2.0], 1, c);
        assert!((l - (c as f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn backward_matches_fd_through_softmax() {
        tcheck::check("softmax_loss_fd", 16, |rng| {
            let n = rng.range_u(1, 4) as usize;
            let c = rng.range_u(2, 6) as usize;
            let mut logits = vec![0.0; n * c];
            rng.fill_uniform(&mut logits, -2.0, 2.0);
            let labels: Vec<f32> = (0..n).map(|_| rng.below(c as u32) as f32).collect();

            let loss_of = |lg: &[f32]| -> f32 {
                let mut p = vec![0.0; n * c];
                softmax_forward(lg, &mut p, n, c);
                softmax_loss_forward(&p, &labels, n, c)
            };

            let mut prob = vec![0.0; n * c];
            softmax_forward(&logits, &mut prob, n, c);
            let mut grad = vec![0.0; n * c];
            softmax_loss_backward(&prob, &labels, &mut grad, n, c, 1.0);

            let eps = 1e-2;
            for i in 0..n * c {
                let mut lp = logits.clone();
                lp[i] += eps;
                let mut lm = logits.clone();
                lm[i] -= eps;
                let fd = (loss_of(&lp) - loss_of(&lm)) / (2.0 * eps);
                if (fd - grad[i]).abs() > 1e-3 {
                    return Err(format!("fd mismatch at {i}: {fd} vs {}", grad[i]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn topk_accuracy() {
        // scores: sample0 best=c2, sample1 best=c0
        let scores = [0.1, 0.2, 0.7, 0.8, 0.1, 0.1];
        let labels = [2.0, 1.0];
        assert_eq!(accuracy(&scores, &labels, 2, 3, 1), 0.5);
        assert_eq!(accuracy(&scores, &labels, 2, 3, 2), 1.0);
    }
}
