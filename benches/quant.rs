//! Reduced-precision serving bench: fp32 vs int8 (and fp16) on the
//! simulated Stratix 10 board, emitting `BENCH_quant.json`.
//!
//! Two legs:
//!
//! * **Simulated time** — deploy forward per zoo net at each precision
//!   on a timing-only `FpgaSimDevice`. The headline `sim_speedup` is
//!   the matmul kernel-engine time (Gemm + Gemv classes, where the
//!   int8 bitstream packs 4 MACs per fp32 lane); `forward_speedup` is
//!   the whole forward including width-scaled DDR/PCIe traffic and the
//!   un-accelerated kernel classes. Simulated clocks are deterministic,
//!   so one measured pass per configuration suffices.
//! * **Top-1 on digits** — train LeNet briefly, then evaluate the same
//!   weights at fp32 and through the emulated int8/fp16 execution path
//!   (fake-quant weights + `QuantBackend` matmuls), reporting the
//!   accuracy delta quantization costs.
//!
//! Self-asserting: int8 matmul speedup must be ≥ 2× on LeNet *and*
//! AlexNet, and the int8 top-1 delta must stay within 1 %.
//!
//! `cargo bench --bench quant`; `FECAFFE_BENCH_QUICK=1` is accepted for
//! CI symmetry (the bench is already quick — it only trims the fp16
//! reporting leg).

use fecaffe::device::fpga::FpgaSimDevice;
use fecaffe::device::{Device, KClass};
use fecaffe::net::Net;
use fecaffe::proto::Phase;
use fecaffe::quant::{self, backend::QuantBackend, Precision};
use fecaffe::solver::Solver;
use fecaffe::util::json::Json;
use fecaffe::zoo;

/// One timing-only deploy forward at `precision`: (forward sim ms,
/// Gemm+Gemv kernel-engine sim ms).
fn sim_forward(name: &str, batch: usize, precision: Precision) -> anyhow::Result<(f64, f64)> {
    let dep = zoo::deploy_by_name(name, batch)?;
    let mut dev = FpgaSimDevice::new().with_precision(precision);
    dev.timing_only = true;
    let mut net = Net::from_param(&dep.param, Phase::Test, &mut dev)?;
    net.forward(&mut dev)?; // warm lazily-created buffers
    dev.reset_timing();
    net.forward(&mut dev)?;
    dev.synchronize();
    let forward_ms = dev.sim_clock_ns().unwrap_or(0) as f64 / 1e6;
    let matmul_ns: u64 = dev
        .profiler
        .stats()
        .iter()
        .filter(|(c, _)| matches!(c, KClass::Gemm | KClass::Gemv))
        .map(|(_, s)| s.total_ns)
        .sum();
    Ok((forward_ms, matmul_ns as f64 / 1e6))
}

/// Evaluate `snap` on the digits test stream at `precision`: fake-quant
/// weights plus the emulated low-precision matmul path — exactly what a
/// `lenet@int8` serving worker executes.
fn eval_top1(snap: &fecaffe::net::WeightSnapshot, precision: Precision) -> anyhow::Result<f32> {
    let mut dev = fecaffe::device::cpu::CpuDevice::new();
    if precision != Precision::Fp32 {
        dev = dev.with_backend(Box::new(QuantBackend::new(precision, None)));
    }
    let param = zoo::by_name("lenet", 100)?;
    let mut net = Net::from_param(&param, Phase::Test, &mut dev)?;
    let weights = quant::prepare_weights(snap, precision);
    net.adopt_weights(&mut dev, &weights)?;
    net.forward(&mut dev)?;
    let acc = net
        .blob("accuracy")
        .ok_or_else(|| anyhow::anyhow!("lenet test net has no accuracy blob"))?
        .borrow_mut()
        .data_vec(&mut dev)[0];
    Ok(acc)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("FECAFFE_BENCH_QUICK")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    let nets: &[(&str, usize)] = &[("lenet", 8), ("alexnet", 8)];

    // Leg 1: simulated forward + matmul-engine time per precision.
    let mut net_rows = Vec::new();
    for &(name, batch) in nets {
        let (fp32_fwd, fp32_mm) = sim_forward(name, batch, Precision::Fp32)?;
        let (int8_fwd, int8_mm) = sim_forward(name, batch, Precision::Int8)?;
        let sim_speedup = fp32_mm / int8_mm.max(1e-12);
        let forward_speedup = fp32_fwd / int8_fwd.max(1e-12);
        println!(
            "{name:>8} @ batch {batch}: matmul {fp32_mm:>8.3} -> {int8_mm:>8.3} ms \
             ({sim_speedup:.2}x), forward {fp32_fwd:>8.3} -> {int8_fwd:>8.3} ms \
             ({forward_speedup:.2}x)"
        );
        anyhow::ensure!(
            sim_speedup >= 2.0,
            "{name}: int8 matmul sim speedup {sim_speedup:.2}x below the 2x floor"
        );
        let mut o = Json::obj();
        o.set("net", Json::str(name));
        o.set("batch", Json::num(batch as f64));
        o.set("fp32_forward_ms", Json::num(fp32_fwd));
        o.set("fp32_matmul_ms", Json::num(fp32_mm));
        o.set("int8_forward_ms", Json::num(int8_fwd));
        o.set("int8_matmul_ms", Json::num(int8_mm));
        o.set("sim_speedup", Json::num(sim_speedup));
        o.set("forward_speedup", Json::num(forward_speedup));
        if !quick {
            let (fp16_fwd, fp16_mm) = sim_forward(name, batch, Precision::Fp16)?;
            o.set("fp16_forward_ms", Json::num(fp16_fwd));
            o.set("fp16_matmul_ms", Json::num(fp16_mm));
            o.set("fp16_sim_speedup", Json::num(fp32_mm / fp16_mm.max(1e-12)));
        }
        net_rows.push(o);
    }

    // Leg 2: top-1 on the digits task, fp32 vs quantized execution of
    // the *same* trained weights.
    let mut dev = fecaffe::device::cpu::CpuDevice::new();
    let param = zoo::by_name("lenet", 32)?;
    let train_net = Net::from_param(&param, Phase::Train, &mut dev)?;
    let mut sp = zoo::default_solver("lenet")?;
    sp.display = 0;
    let mut solver = Solver::new(sp, train_net, &mut dev)?;
    let steps = 60;
    for _ in 0..steps {
        solver.step(&mut dev)?;
    }
    let snap = solver.net.share_weights(&mut dev);

    let top1_fp32 = eval_top1(&snap, Precision::Fp32)?;
    let top1_int8 = eval_top1(&snap, Precision::Int8)?;
    let top1_fp16 = eval_top1(&snap, Precision::Fp16)?;
    let delta_int8 = (top1_fp32 - top1_int8).abs();
    println!(
        "lenet digits top-1: fp32 {top1_fp32:.3}, int8 {top1_int8:.3} \
         (delta {delta_int8:.3}), fp16 {top1_fp16:.3}"
    );
    anyhow::ensure!(
        delta_int8 <= 0.01,
        "int8 top-1 delta {delta_int8:.3} exceeds the 1% budget"
    );

    let mut acc = Json::obj();
    acc.set("net", Json::str("lenet"));
    acc.set("train_steps", Json::num(steps as f64));
    acc.set("eval_batch", Json::num(100.0));
    acc.set("top1_fp32", Json::num(f64::from(top1_fp32)));
    acc.set("top1_int8", Json::num(f64::from(top1_int8)));
    acc.set("top1_fp16", Json::num(f64::from(top1_fp16)));
    acc.set("top1_delta_int8", Json::num(f64::from(delta_int8)));

    let mut root = Json::obj();
    root.set("bench", Json::str("quant"));
    root.set("quick", Json::Bool(quick));
    root.set("nets", Json::arr(net_rows));
    root.set("accuracy", acc);
    std::fs::write("BENCH_quant.json", root.to_pretty())?;
    println!("wrote BENCH_quant.json");
    Ok(())
}
