//! CPU fallback device (paper §3.3 "fallback mechanism on CPU").
//!
//! Buffers live in the host slab; `write`/`read` are plain copies with no
//! transfer billing. Kernels execute through the native math library.
//! This device doubles as the correctness oracle for the FPGA simulator
//! in the equivalence tests.

use super::fpga::NumericBackend;
use super::native::{execute, Slab};
use super::{BufId, Device, KernelCall, ScratchAction, ScratchPool};
use crate::util::pool;

#[derive(Default)]
pub struct CpuDevice {
    slab: Slab,
    launches: u64,
    scratch: ScratchPool,
    /// Intra-op thread cap applied around kernel execution (0 = inherit
    /// the calling thread's budget / process default).
    intra_op: usize,
    /// Optional numeric backend consulted before native math (the quant
    /// emulation path and the calibration range observer plug in here,
    /// mirroring the FPGA simulator's backend seam).
    backend: Option<Box<dyn NumericBackend>>,
}

impl CpuDevice {
    pub fn new() -> CpuDevice {
        CpuDevice::default()
    }

    /// Cap this device's kernels at `threads` intra-op threads (0 clears
    /// the cap). Serving workers use this so N inter-op workers × their
    /// intra-op pools never oversubscribe the machine.
    pub fn with_intra_op(mut self, threads: usize) -> CpuDevice {
        self.intra_op = threads;
        self
    }

    /// Route kernels through `backend` first; calls it declines
    /// (`Ok(false)`) fall back to native math.
    pub fn with_backend(mut self, backend: Box<dyn NumericBackend>) -> CpuDevice {
        self.backend = Some(backend);
        self
    }

    pub fn launches(&self) -> u64 {
        self.launches
    }

    /// Direct slab access for tests.
    pub fn buffer(&self, id: BufId) -> &[f32] {
        self.slab.get(id)
    }
}

impl Device for CpuDevice {
    fn kind(&self) -> &'static str {
        "cpu"
    }

    fn alloc(&mut self, len: usize) -> anyhow::Result<BufId> {
        Ok(self.slab.alloc(len))
    }

    fn free(&mut self, id: BufId) {
        self.slab.free(id);
    }

    fn write(&mut self, id: BufId, data: &[f32]) {
        let buf = self.slab.get_mut(id);
        assert!(
            data.len() <= buf.len(),
            "write of {} into buffer of {}",
            data.len(),
            buf.len()
        );
        buf[..data.len()].copy_from_slice(data);
    }

    fn read(&mut self, id: BufId, out: &mut [f32]) {
        let buf = self.slab.get(id);
        assert!(out.len() <= buf.len());
        out.copy_from_slice(&buf[..out.len()]);
    }

    fn launch(&mut self, call: &KernelCall) -> anyhow::Result<()> {
        self.launches += 1;
        let slab = &mut self.slab;
        let backend = &mut self.backend;
        pool::with_intra_op(self.intra_op, || {
            if let Some(b) = backend {
                if b.execute(slab, call)? {
                    return Ok(());
                }
            }
            execute(slab, call)
        })
    }

    fn scratch(&mut self, slot: usize, len: usize) -> anyhow::Result<BufId> {
        match self.scratch.plan(slot, len) {
            ScratchAction::Use(id) => Ok(id),
            ScratchAction::Grow(old) => {
                if let Some(id) = old {
                    self.slab.free(id);
                }
                let id = self.slab.alloc(len);
                self.scratch.commit(slot, id, len);
                Ok(id)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Kernel;

    #[test]
    fn device_roundtrip_and_launch() {
        let mut dev = CpuDevice::new();
        let x = dev.alloc(3).unwrap();
        let y = dev.alloc(3).unwrap();
        dev.write(x, &[1.0, -2.0, 3.0]);
        dev.write(y, &[0.0, 0.0, 0.0]);
        dev.launch(&KernelCall::new(
            Kernel::ReluF { n: 3, slope: 0.0 },
            &[x],
            &[y],
        ))
        .unwrap();
        let mut out = [0.0f32; 3];
        dev.read(y, &mut out);
        assert_eq!(out, [1.0, 0.0, 3.0]);
        assert_eq!(dev.launches(), 1);
        assert!(dev.sim_clock_ns().is_none());
    }
}
