//! Softmax layer (deploy-model head; kernel `Softmax`).

use super::{Layer, SharedBlob};
use crate::device::{Device, Kernel, KernelCall};
use crate::proto::LayerParameter;

pub struct SoftmaxLayer {
    name: String,
    n: usize,
    c: usize,
}

impl SoftmaxLayer {
    pub fn new(param: &LayerParameter) -> SoftmaxLayer {
        SoftmaxLayer { name: param.name.clone(), n: 0, c: 0 }
    }
}

impl Layer for SoftmaxLayer {
    fn name(&self) -> &str {
        &self.name
    }
    fn kind(&self) -> &'static str {
        "Softmax"
    }

    fn setup(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        self.reshape(dev, bottoms, tops)
    }

    fn reshape(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<()> {
        let b = bottoms[0].borrow();
        self.n = b.num();
        self.c = b.count() / self.n.max(1);
        let shape = b.shape().to_vec();
        drop(b);
        tops[0].borrow_mut().reshape_grow_only(dev, &shape);
        Ok(())
    }

    fn forward(
        &mut self,
        dev: &mut dyn Device,
        bottoms: &[SharedBlob],
        tops: &[SharedBlob],
    ) -> anyhow::Result<f32> {
        let b_id = bottoms[0].borrow_mut().data.dev_data(dev);
        let t_id = tops[0].borrow_mut().data.dev_data_mut(dev);
        dev.launch(&KernelCall::new(
            Kernel::SoftmaxF { n: self.n, c: self.c },
            &[b_id],
            &[t_id],
        ))?;
        Ok(0.0)
    }

    fn backward(
        &mut self,
        _dev: &mut dyn Device,
        _tops: &[SharedBlob],
        _prop_down: &[bool],
        _bottoms: &[SharedBlob],
    ) -> anyhow::Result<()> {
        // Deploy-only head in this zoo (training nets use SoftmaxWithLoss).
        anyhow::bail!("Softmax layer backward is not used by the zoo's training nets")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blob::Blob;
    use crate::device::cpu::CpuDevice;

    #[test]
    fn rows_sum_to_one() {
        let mut dev = CpuDevice::new();
        let mut layer = SoftmaxLayer::new(&LayerParameter::new("s", "Softmax"));
        let bottom = super::super::shared(Blob::new("x", &[2, 3]));
        let top = super::super::shared(Blob::new("y", &[1]));
        bottom
            .borrow_mut()
            .set_data(&mut dev, &[1.0, 2.0, 3.0, 0.0, 0.0, 0.0]);
        layer.setup(&mut dev, &[bottom.clone()], &[top.clone()]).unwrap();
        layer.forward(&mut dev, &[bottom], &[top.clone()]).unwrap();
        let out = top.borrow_mut().data_vec(&mut dev);
        assert!((out[0..3].iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!((out[3] - 1.0 / 3.0).abs() < 1e-6);
    }
}
