//! Focused coverage for two substrate pieces the serving engine leans
//! on: `runtime::plan::bucket` (artifact-count bounding) and the
//! `device::ScratchPool` plan/commit protocol both devices implement.

use fecaffe::device::cpu::CpuDevice;
use fecaffe::device::{BufId, Device, ScratchAction, ScratchPool};
use fecaffe::runtime::plan::bucket;

// ------------------------------------------------------------- bucket

#[test]
fn bucket_minimum_is_256() {
    assert_eq!(bucket(0), 256);
    assert_eq!(bucket(1), 256);
    assert_eq!(bucket(255), 256);
    assert_eq!(bucket(256), 256);
}

#[test]
fn bucket_rounds_up_to_powers_of_two() {
    assert_eq!(bucket(257), 512);
    assert_eq!(bucket(512), 512);
    assert_eq!(bucket(513), 1024);
    assert_eq!(bucket(100_000), 131_072);
    assert_eq!(bucket(1 << 20), 1 << 20);
    // Power-of-two outputs all the way up to the exact-size threshold.
    for n in [2usize, 300, 5_000, 900_000] {
        assert!(bucket(n).is_power_of_two(), "bucket({n})");
        assert!(bucket(n) >= n);
    }
}

#[test]
fn bucket_is_exact_above_two_pow_twenty() {
    // Padding 37M-element FC weights to 64M would double the traffic —
    // above 2^20 the bucket is the exact size.
    assert_eq!(bucket((1 << 20) + 1), (1 << 20) + 1);
    assert_eq!(bucket(37_748_736), 37_748_736);
    assert_eq!(bucket((1 << 26) + 123), (1 << 26) + 123);
}

#[test]
fn bucket_is_monotonic_and_idempotent() {
    let mut prev = 0;
    for n in (0..4096).step_by(7) {
        let b = bucket(n);
        assert!(b >= prev, "bucket must be monotonic at {n}");
        assert_eq!(bucket(b), b, "bucket must be a fixed point at {n}");
        prev = b;
    }
}

// -------------------------------------------------------- ScratchPool

#[test]
fn scratch_pool_first_request_grows_from_nothing() {
    let mut pool = ScratchPool::new();
    match pool.plan(0, 100) {
        ScratchAction::Grow(None) => {}
        ScratchAction::Grow(Some(_)) => panic!("nothing to free on first use"),
        ScratchAction::Use(_) => panic!("nothing to reuse on first use"),
    }
}

#[test]
fn scratch_pool_reuses_committed_capacity() {
    let mut pool = ScratchPool::new();
    assert!(matches!(pool.plan(0, 100), ScratchAction::Grow(None)));
    pool.commit(0, BufId(7), 100);
    // Equal and smaller requests reuse the committed buffer.
    match pool.plan(0, 100) {
        ScratchAction::Use(id) => assert_eq!(id, BufId(7)),
        _ => panic!("expected Use"),
    }
    match pool.plan(0, 40) {
        ScratchAction::Use(id) => assert_eq!(id, BufId(7)),
        _ => panic!("expected Use for smaller request"),
    }
}

#[test]
fn scratch_pool_grow_hands_back_old_buffer() {
    let mut pool = ScratchPool::new();
    pool.plan(0, 100);
    pool.commit(0, BufId(7), 100);
    match pool.plan(0, 200) {
        ScratchAction::Grow(Some(old)) => assert_eq!(old, BufId(7)),
        _ => panic!("larger request must grow and free the old buffer"),
    }
    pool.commit(0, BufId(9), 200);
    // The grown capacity now serves requests the old one couldn't.
    match pool.plan(0, 150) {
        ScratchAction::Use(id) => assert_eq!(id, BufId(9)),
        _ => panic!("expected Use after growth"),
    }
}

#[test]
fn scratch_pool_slots_are_independent() {
    let mut pool = ScratchPool::new();
    pool.plan(0, 10);
    pool.commit(0, BufId(1), 10);
    // A far slot starts empty even though slot 0 is committed.
    assert!(matches!(pool.plan(3, 10), ScratchAction::Grow(None)));
    pool.commit(3, BufId(2), 10);
    match (pool.plan(0, 10), pool.plan(3, 10)) {
        (ScratchAction::Use(a), ScratchAction::Use(b)) => {
            assert_eq!(a, BufId(1));
            assert_eq!(b, BufId(2));
        }
        _ => panic!("both slots must reuse their own buffers"),
    }
}

#[test]
fn cpu_device_scratch_follows_plan_commit() {
    let mut dev = CpuDevice::new();
    let a = dev.scratch(0, 64).unwrap();
    let b = dev.scratch(0, 64).unwrap();
    assert_eq!(a, b, "same-size scratch request must reuse the buffer");
    let c = dev.scratch(0, 32).unwrap();
    assert_eq!(a, c, "smaller scratch request must reuse the buffer");
    let d = dev.scratch(1, 64).unwrap();
    assert_ne!(a, d, "slots are distinct buffers");
    // Growth re-allocates but the committed id keeps serving afterwards.
    let e = dev.scratch(0, 1024).unwrap();
    let f = dev.scratch(0, 512).unwrap();
    assert_eq!(e, f);
}
