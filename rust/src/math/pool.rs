//! Max/Average pooling forward + backward (paper kernels `Max_pool_F/B`,
//! `Ave_pool_F/B`). Follows Caffe's geometry: ceil-mode output sizing and
//! clipping at the (padded) borders.
//!
//! The single-image kernels are the numerics; the `*_batch` entry points
//! (what the native executor launches) shard the per-image loop across
//! the intra-op pool — image `i` owns disjoint slices of every operand,
//! so batching is embarrassingly parallel and thread-count invariant.

use super::im2col::ConvGeom;
use crate::util::pool as thr;

/// Pooled output size, Caffe style (ceil), with the guarantee that the
/// last window starts inside the (unpadded) image.
pub fn pooled_dim(input: usize, kernel: usize, pad: usize, stride: usize) -> usize {
    let mut out = ((input + 2 * pad - kernel) as f64 / stride as f64).ceil() as usize + 1;
    if pad > 0 {
        // Clip last pooling window to start strictly inside image + pad.
        if (out - 1) * stride >= input + pad {
            out -= 1;
        }
    }
    out
}

/// Geometry helper mirroring ConvGeom but with pooling output rules.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolGeom {
    pub channels: usize,
    pub height: usize,
    pub width: usize,
    pub kernel_h: usize,
    pub kernel_w: usize,
    pub pad_h: usize,
    pub pad_w: usize,
    pub stride_h: usize,
    pub stride_w: usize,
}

impl PoolGeom {
    pub fn out_h(&self) -> usize {
        pooled_dim(self.height, self.kernel_h, self.pad_h, self.stride_h)
    }
    pub fn out_w(&self) -> usize {
        pooled_dim(self.width, self.kernel_w, self.pad_w, self.stride_w)
    }
    pub fn in_len(&self) -> usize {
        self.channels * self.height * self.width
    }
    pub fn out_len(&self) -> usize {
        self.channels * self.out_h() * self.out_w()
    }
    pub fn as_conv(&self) -> ConvGeom {
        ConvGeom {
            channels: self.channels,
            height: self.height,
            width: self.width,
            kernel_h: self.kernel_h,
            kernel_w: self.kernel_w,
            pad_h: self.pad_h,
            pad_w: self.pad_w,
            stride_h: self.stride_h,
            stride_w: self.stride_w,
        }
    }
}

/// Max pooling forward for one image; writes the argmax index (into the
/// per-channel plane) to `mask` for the backward pass.
pub fn max_pool_forward(g: &PoolGeom, bottom: &[f32], top: &mut [f32], mask: &mut [f32]) {
    assert!(bottom.len() >= g.in_len());
    assert!(top.len() >= g.out_len() && mask.len() >= g.out_len());
    let (oh, ow) = (g.out_h(), g.out_w());
    for c in 0..g.channels {
        let plane = &bottom[c * g.height * g.width..(c + 1) * g.height * g.width];
        for y in 0..oh {
            for x in 0..ow {
                let hs = (y * g.stride_h) as isize - g.pad_h as isize;
                let ws = (x * g.stride_w) as isize - g.pad_w as isize;
                let he = (hs + g.kernel_h as isize).min(g.height as isize);
                let we = (ws + g.kernel_w as isize).min(g.width as isize);
                let hs = hs.max(0) as usize;
                let ws = ws.max(0) as usize;
                let mut best = f32::NEG_INFINITY;
                let mut best_idx = 0usize;
                for iy in hs..he as usize {
                    for ix in ws..we as usize {
                        let idx = iy * g.width + ix;
                        if plane[idx] > best {
                            best = plane[idx];
                            best_idx = idx;
                        }
                    }
                }
                let o = (c * oh + y) * ow + x;
                top[o] = best;
                mask[o] = best_idx as f32;
            }
        }
    }
}

/// Max pooling backward: route top_diff to the argmax positions.
/// `bottom_diff` must be zeroed by the caller.
pub fn max_pool_backward(g: &PoolGeom, top_diff: &[f32], mask: &[f32], bottom_diff: &mut [f32]) {
    assert!(bottom_diff.len() >= g.in_len());
    let (oh, ow) = (g.out_h(), g.out_w());
    assert!(top_diff.len() >= g.out_len() && mask.len() >= g.out_len());
    for c in 0..g.channels {
        let plane_base = c * g.height * g.width;
        for y in 0..oh {
            for x in 0..ow {
                let o = (c * oh + y) * ow + x;
                bottom_diff[plane_base + mask[o] as usize] += top_diff[o];
            }
        }
    }
}

/// Average pooling forward for one image. Caffe divides by the *padded*
/// window size (clipped to padded borders).
pub fn ave_pool_forward(g: &PoolGeom, bottom: &[f32], top: &mut [f32]) {
    assert!(bottom.len() >= g.in_len() && top.len() >= g.out_len());
    let (oh, ow) = (g.out_h(), g.out_w());
    for c in 0..g.channels {
        let plane = &bottom[c * g.height * g.width..(c + 1) * g.height * g.width];
        for y in 0..oh {
            for x in 0..ow {
                let hs0 = (y * g.stride_h) as isize - g.pad_h as isize;
                let ws0 = (x * g.stride_w) as isize - g.pad_w as isize;
                let he0 = (hs0 + g.kernel_h as isize).min((g.height + g.pad_h) as isize);
                let we0 = (ws0 + g.kernel_w as isize).min((g.width + g.pad_w) as isize);
                let pool_size = ((he0 - hs0) * (we0 - ws0)) as f32;
                let hs = hs0.max(0) as usize;
                let ws = ws0.max(0) as usize;
                let he = he0.min(g.height as isize) as usize;
                let we = we0.min(g.width as isize) as usize;
                let mut acc = 0.0f32;
                for iy in hs..he {
                    for ix in ws..we {
                        acc += plane[iy * g.width + ix];
                    }
                }
                top[(c * oh + y) * ow + x] = acc / pool_size;
            }
        }
    }
}

/// Average pooling backward. `bottom_diff` must be zeroed by the caller.
pub fn ave_pool_backward(g: &PoolGeom, top_diff: &[f32], bottom_diff: &mut [f32]) {
    assert!(bottom_diff.len() >= g.in_len() && top_diff.len() >= g.out_len());
    let (oh, ow) = (g.out_h(), g.out_w());
    for c in 0..g.channels {
        let plane_base = c * g.height * g.width;
        for y in 0..oh {
            for x in 0..ow {
                let hs0 = (y * g.stride_h) as isize - g.pad_h as isize;
                let ws0 = (x * g.stride_w) as isize - g.pad_w as isize;
                let he0 = (hs0 + g.kernel_h as isize).min((g.height + g.pad_h) as isize);
                let we0 = (ws0 + g.kernel_w as isize).min((g.width + g.pad_w) as isize);
                let pool_size = ((he0 - hs0) * (we0 - ws0)) as f32;
                let hs = hs0.max(0) as usize;
                let ws = ws0.max(0) as usize;
                let he = he0.min(g.height as isize) as usize;
                let we = we0.min(g.width as isize) as usize;
                let g_share = top_diff[(c * oh + y) * ow + x] / pool_size;
                for iy in hs..he {
                    for ix in ws..we {
                        bottom_diff[plane_base + iy * g.width + ix] += g_share;
                    }
                }
            }
        }
    }
}

/// Batched max-pool forward: `num` images, images sharded across the
/// intra-op pool.
pub fn max_pool_forward_batch(
    g: &PoolGeom,
    num: usize,
    bottom: &[f32],
    top: &mut [f32],
    mask: &mut [f32],
) {
    let (il, ol) = (g.in_len(), g.out_len());
    assert!(bottom.len() >= num * il);
    assert!(top.len() >= num * ol && mask.len() >= num * ol);
    let tp = thr::SendPtr::new(top.as_mut_ptr());
    let mp = thr::SendPtr::new(mask.as_mut_ptr());
    thr::parallel_for(0..num, 1, |r| {
        for i in r {
            // Safety: image slices are disjoint across tasks.
            let t = unsafe { tp.slice(i * ol, ol) };
            let m = unsafe { mp.slice(i * ol, ol) };
            max_pool_forward(g, &bottom[i * il..(i + 1) * il], t, m);
        }
    });
}

/// Batched max-pool backward. Zeroes `bottom_diff[..num*in_len]` itself,
/// then routes each image's gradient — image planes are disjoint.
pub fn max_pool_backward_batch(
    g: &PoolGeom,
    num: usize,
    top_diff: &[f32],
    mask: &[f32],
    bottom_diff: &mut [f32],
) {
    let (il, ol) = (g.in_len(), g.out_len());
    assert!(top_diff.len() >= num * ol && mask.len() >= num * ol);
    assert!(bottom_diff.len() >= num * il);
    let bp = thr::SendPtr::new(bottom_diff.as_mut_ptr());
    thr::parallel_for(0..num, 1, |r| {
        for i in r {
            // Safety: image slices are disjoint across tasks.
            let bd = unsafe { bp.slice(i * il, il) };
            for v in bd.iter_mut() {
                *v = 0.0;
            }
            max_pool_backward(g, &top_diff[i * ol..(i + 1) * ol], &mask[i * ol..(i + 1) * ol], bd);
        }
    });
}

/// Batched average-pool forward.
pub fn ave_pool_forward_batch(g: &PoolGeom, num: usize, bottom: &[f32], top: &mut [f32]) {
    let (il, ol) = (g.in_len(), g.out_len());
    assert!(bottom.len() >= num * il && top.len() >= num * ol);
    let tp = thr::SendPtr::new(top.as_mut_ptr());
    thr::parallel_for(0..num, 1, |r| {
        for i in r {
            // Safety: image slices are disjoint across tasks.
            let t = unsafe { tp.slice(i * ol, ol) };
            ave_pool_forward(g, &bottom[i * il..(i + 1) * il], t);
        }
    });
}

/// Batched average-pool backward. Zeroes `bottom_diff[..num*in_len]`.
pub fn ave_pool_backward_batch(
    g: &PoolGeom,
    num: usize,
    top_diff: &[f32],
    bottom_diff: &mut [f32],
) {
    let (il, ol) = (g.in_len(), g.out_len());
    assert!(top_diff.len() >= num * ol && bottom_diff.len() >= num * il);
    let bp = thr::SendPtr::new(bottom_diff.as_mut_ptr());
    thr::parallel_for(0..num, 1, |r| {
        for i in r {
            // Safety: image slices are disjoint across tasks.
            let bd = unsafe { bp.slice(i * il, il) };
            for v in bd.iter_mut() {
                *v = 0.0;
            }
            ave_pool_backward(g, &top_diff[i * ol..(i + 1) * ol], bd);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tcheck;

    fn g2x2() -> PoolGeom {
        PoolGeom {
            channels: 1,
            height: 4,
            width: 4,
            kernel_h: 2,
            kernel_w: 2,
            pad_h: 0,
            pad_w: 0,
            stride_h: 2,
            stride_w: 2,
        }
    }

    #[test]
    fn caffe_output_sizing() {
        // AlexNet pool1: 55x55, k3 s2 → 27? Caffe ceil mode: (55-3)/2+1 = 27
        assert_eq!(pooled_dim(55, 3, 0, 2), 27);
        // GoogLeNet pool1: 112, k3 s2 → ceil((112-3)/2)+1 = 56
        assert_eq!(pooled_dim(112, 3, 0, 2), 56);
        // ceil kicks in: 7, k3 s2 → ceil(4/2)+1 = 3
        assert_eq!(pooled_dim(7, 3, 0, 2), 3);
        // SqueezeNet pool: 111 k3 s2 → ceil(108/2)+1 = 55
        assert_eq!(pooled_dim(111, 3, 0, 2), 55);
    }

    #[test]
    fn max_forward_and_mask() {
        let g = g2x2();
        let bottom: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let mut top = vec![0.0; g.out_len()];
        let mut mask = vec![0.0; g.out_len()];
        max_pool_forward(&g, &bottom, &mut top, &mut mask);
        assert_eq!(top, vec![5.0, 7.0, 13.0, 15.0]);
        assert_eq!(mask, vec![5.0, 7.0, 13.0, 15.0]); // indices match values here
    }

    #[test]
    fn max_backward_routes_to_argmax() {
        let g = g2x2();
        let bottom: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let mut top = vec![0.0; 4];
        let mut mask = vec![0.0; 4];
        max_pool_forward(&g, &bottom, &mut top, &mut mask);
        let mut bd = vec![0.0; 16];
        max_pool_backward(&g, &[1.0, 2.0, 3.0, 4.0], &mask, &mut bd);
        assert_eq!(bd[5], 1.0);
        assert_eq!(bd[7], 2.0);
        assert_eq!(bd[13], 3.0);
        assert_eq!(bd[15], 4.0);
        assert_eq!(bd.iter().sum::<f32>(), 10.0);
    }

    #[test]
    fn ave_forward_simple() {
        let g = g2x2();
        let bottom = vec![1.0; 16];
        let mut top = vec![0.0; 4];
        ave_pool_forward(&g, &bottom, &mut top);
        assert_eq!(top, vec![1.0; 4]);
    }

    #[test]
    fn ave_global_pool() {
        // GoogLeNet pool5: 7x7 global average.
        let g = PoolGeom {
            channels: 2,
            height: 7,
            width: 7,
            kernel_h: 7,
            kernel_w: 7,
            pad_h: 0,
            pad_w: 0,
            stride_h: 1,
            stride_w: 1,
        };
        assert_eq!((g.out_h(), g.out_w()), (1, 1));
        let mut bottom = vec![2.0; g.in_len()];
        for v in bottom[49..].iter_mut() {
            *v = 4.0;
        }
        let mut top = vec![0.0; 2];
        ave_pool_forward(&g, &bottom, &mut top);
        assert_eq!(top, vec![2.0, 4.0]);
    }

    /// Gradient check: pooling backward == finite differences of forward.
    #[test]
    fn pool_gradients_match_fd() {
        tcheck::check("pool_fd", 16, |rng| {
            let g = PoolGeom {
                channels: rng.range_u(1, 2) as usize,
                height: rng.range_u(3, 6) as usize,
                width: rng.range_u(3, 6) as usize,
                kernel_h: 2,
                kernel_w: 2,
                pad_h: 0,
                pad_w: 0,
                stride_h: rng.range_u(1, 2) as usize,
                stride_w: rng.range_u(1, 2) as usize,
            };
            let mut bottom = vec![0.0; g.in_len()];
            rng.fill_uniform(&mut bottom, -1.0, 1.0);
            // random top_diff
            let mut td = vec![0.0; g.out_len()];
            rng.fill_uniform(&mut td, -1.0, 1.0);

            for ave in [false, true] {
                let fwd = |b: &[f32]| -> Vec<f32> {
                    let mut t = vec![0.0; g.out_len()];
                    if ave {
                        ave_pool_forward(&g, b, &mut t);
                    } else {
                        let mut m = vec![0.0; g.out_len()];
                        max_pool_forward(&g, b, &mut t, &mut m);
                    }
                    t
                };
                let mut bd = vec![0.0; g.in_len()];
                if ave {
                    ave_pool_backward(&g, &td, &mut bd);
                } else {
                    let mut t = vec![0.0; g.out_len()];
                    let mut m = vec![0.0; g.out_len()];
                    max_pool_forward(&g, &bottom, &mut t, &mut m);
                    max_pool_backward(&g, &td, &m, &mut bd);
                }
                let eps = 1e-3;
                for i in 0..bottom.len() {
                    let mut bp = bottom.clone();
                    bp[i] += eps;
                    let mut bm = bottom.clone();
                    bm[i] -= eps;
                    let fp = fwd(&bp);
                    let fm = fwd(&bm);
                    let fd: f32 = fp
                        .iter()
                        .zip(fm.iter())
                        .zip(td.iter())
                        .map(|((p, m_), t)| (p - m_) / (2.0 * eps) * t)
                        .sum();
                    // max-pool FD near ties is unstable; tolerate generously
                    let tol = if ave { 1e-3 } else { 0.35 };
                    if (fd - bd[i]).abs() > tol {
                        return Err(format!(
                            "pool fd mismatch ave={ave} at {i}: {fd} vs {} ({g:?})",
                            bd[i]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// The batched (parallel) entry points must equal a serial per-image
    /// loop bit for bit.
    #[test]
    fn batch_matches_serial_loop() {
        let g = g2x2();
        let num = 9;
        let (il, ol) = (g.in_len(), g.out_len());
        let mut rng = crate::util::prng::Pcg32::new(21);
        let mut bottom = vec![0.0; num * il];
        rng.fill_uniform(&mut bottom, -1.0, 1.0);
        let mut td = vec![0.0; num * ol];
        rng.fill_uniform(&mut td, -1.0, 1.0);

        let (mut top_b, mut mask_b) = (vec![0.0; num * ol], vec![0.0; num * ol]);
        max_pool_forward_batch(&g, num, &bottom, &mut top_b, &mut mask_b);
        let (mut top_s, mut mask_s) = (vec![0.0; num * ol], vec![0.0; num * ol]);
        for i in 0..num {
            max_pool_forward(
                &g,
                &bottom[i * il..(i + 1) * il],
                &mut top_s[i * ol..(i + 1) * ol],
                &mut mask_s[i * ol..(i + 1) * ol],
            );
        }
        assert_eq!(top_b, top_s);
        assert_eq!(mask_b, mask_s);

        let mut bd_b = vec![7.0; num * il]; // pre-filled: batch must zero it
        max_pool_backward_batch(&g, num, &td, &mask_b, &mut bd_b);
        let mut bd_s = vec![0.0; num * il];
        for i in 0..num {
            max_pool_backward(
                &g,
                &td[i * ol..(i + 1) * ol],
                &mask_s[i * ol..(i + 1) * ol],
                &mut bd_s[i * il..(i + 1) * il],
            );
        }
        assert_eq!(bd_b, bd_s);

        let mut at_b = vec![0.0; num * ol];
        ave_pool_forward_batch(&g, num, &bottom, &mut at_b);
        let mut abd_b = vec![7.0; num * il];
        ave_pool_backward_batch(&g, num, &td, &mut abd_b);
        let mut at_s = vec![0.0; num * ol];
        let mut abd_s = vec![0.0; num * il];
        for i in 0..num {
            ave_pool_forward(&g, &bottom[i * il..(i + 1) * il], &mut at_s[i * ol..(i + 1) * ol]);
            ave_pool_backward(&g, &td[i * ol..(i + 1) * ol], &mut abd_s[i * il..(i + 1) * il]);
        }
        assert_eq!(at_b, at_s);
        assert_eq!(abd_b, abd_s);
    }
}
