//! Pass 2: allocation-free shape inference.
//!
//! Replays every layer's `reshape` geometry — the same formulas
//! [`crate::math::ConvGeom`] / [`crate::math::pool::pooled_dim`] and the
//! per-layer reshape impls use — over the *split-inserted* layer list,
//! so the resulting blob-name → shape map is bit-identical to a built
//! [`crate::net::Net`] after `reshape_batch` (the property suite asserts
//! this for every zoo net × serving bucket). No blob is allocated and no
//! device is touched.
//!
//! Geometry findings: `NL0101` invalid kernel/stride geometry, `NL0102`
//! group/channel mismatch, `NL0103` inconsistent bottom shapes, `NL0104`
//! wrong arity or missing/invalid layer params, `NL0105` unknown layer
//! kind. A layer that cannot be inferred marks its tops unknown, so one
//! root cause does not cascade into downstream noise.

use super::LintDiagnostic;
use crate::math::pool::pooled_dim;
use crate::proto::{LayerParameter, NetParameter, Phase};
use std::collections::{BTreeMap, HashSet};

/// Mirror of `Blob::num/channels/height/width`: missing trailing axes
/// default to 1.
fn dim(shape: &[usize], i: usize) -> usize {
    shape.get(i).copied().unwrap_or(1)
}

fn count(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Infer shapes for a split-inserted layer list. `batch` rewrites the
/// *first* explicit input's leading dimension (exactly like
/// [`crate::net::Net::reshape_batch`]); data-layer-fed nets ignore it
/// (the data layer re-asserts its configured batch, as at runtime).
pub fn infer_with_splits(
    with_splits: &[LayerParameter],
    inputs: &[(String, [usize; 4])],
    batch: Option<usize>,
    diags: &mut Vec<LintDiagnostic>,
) -> BTreeMap<String, Vec<usize>> {
    let mut shapes: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    let mut unknown: HashSet<String> = HashSet::new();

    for (i, (name, shape)) in inputs.iter().enumerate() {
        let mut s = shape.to_vec();
        if i == 0 {
            if let Some(b) = batch {
                s[0] = b;
            }
        }
        shapes.insert(name.clone(), s);
    }

    'layers: for lp in with_splits {
        // Resolve bottoms; a missing bottom is a *graph* finding (pass 1
        // owns it) — here we just stop propagating through this layer.
        let mut bots: Vec<Vec<usize>> = Vec::with_capacity(lp.bottoms.len());
        for b in &lp.bottoms {
            match shapes.get(b) {
                Some(s) => bots.push(s.clone()),
                None => {
                    unknown.extend(lp.tops.iter().cloned());
                    continue 'layers;
                }
            }
        }
        let tops = infer_layer(lp, &bots, diags);
        match tops {
            Some(tops) => {
                for (t, s) in lp.tops.iter().zip(tops) {
                    shapes.insert(t.clone(), s);
                }
            }
            None => unknown.extend(lp.tops.iter().cloned()),
        }
    }
    shapes
}

/// Expected (bottoms, tops) arity per layer kind; `None` = variadic.
fn arity(kind: &str) -> Option<(usize, usize)> {
    match kind {
        "SyntheticData" | "Data" => Some((0, 2)),
        "Convolution" | "Pooling" | "InnerProduct" | "ReLU" | "Dropout" | "LRN"
        | "Softmax" => Some((1, 1)),
        "SoftmaxWithLoss" | "Accuracy" => Some((2, 1)),
        "Concat" | "Split" => None,
        _ => None,
    }
}

/// Compute the top shapes of one layer, or `None` if they cannot be
/// determined (a diagnostic explains why).
fn infer_layer(
    lp: &LayerParameter,
    bots: &[Vec<usize>],
    diags: &mut Vec<LintDiagnostic>,
) -> Option<Vec<Vec<usize>>> {
    let name = lp.name.as_str();
    if let Some((nb, nt)) = arity(&lp.kind) {
        if lp.bottoms.len() != nb || lp.tops.len() != nt {
            diags.push(LintDiagnostic::error(
                "NL0104",
                Some(name),
                format!(
                    "{} expects {nb} bottom(s) and {nt} top(s), has {} and {}",
                    lp.kind,
                    lp.bottoms.len(),
                    lp.tops.len()
                ),
            ));
            return None;
        }
    }
    match lp.kind.as_str() {
        "SyntheticData" | "Data" => {
            let p = match &lp.data {
                Some(p) => p,
                None => {
                    diags.push(LintDiagnostic::error(
                        "NL0104",
                        Some(name),
                        "data layer has no data_param".into(),
                    ));
                    return None;
                }
            };
            // Mirror `data::create_source`: the "digits" source is
            // single-channel regardless of the declared channel count.
            let c = match p.source.as_str() {
                "digits" => 1,
                "imagenet" => p.channels,
                other => {
                    diags.push(LintDiagnostic::error(
                        "NL0104",
                        Some(name),
                        format!("unknown synthetic data source '{other}'"),
                    ));
                    return None;
                }
            };
            Some(vec![
                vec![p.batch_size, c, p.height, p.width],
                vec![p.batch_size],
            ])
        }
        "Convolution" => {
            let p = match &lp.conv {
                Some(p) => p,
                None => {
                    diags.push(LintDiagnostic::error(
                        "NL0104",
                        Some(name),
                        "convolution layer has no convolution_param".into(),
                    ));
                    return None;
                }
            };
            let (n, c, h, w) = nchw(&bots[0]);
            if p.stride_h == 0 || p.stride_w == 0 || p.kernel_h == 0 || p.kernel_w == 0 {
                diags.push(LintDiagnostic::error(
                    "NL0101",
                    Some(name),
                    format!(
                        "invalid geometry: kernel {}x{}, stride {}x{} (must be >= 1)",
                        p.kernel_h, p.kernel_w, p.stride_h, p.stride_w
                    ),
                ));
                return None;
            }
            if p.group == 0 || c % p.group != 0 || p.num_output % p.group != 0 {
                diags.push(LintDiagnostic::error(
                    "NL0102",
                    Some(name),
                    format!(
                        "channels {c} / num_output {} not divisible by group {}",
                        p.num_output, p.group
                    ),
                ));
                return None;
            }
            if h + 2 * p.pad_h < p.kernel_h || w + 2 * p.pad_w < p.kernel_w {
                diags.push(
                    LintDiagnostic::error(
                        "NL0101",
                        Some(name),
                        format!(
                            "kernel {}x{} exceeds padded input {}x{} (pad {}x{})",
                            p.kernel_h, p.kernel_w, h, w, p.pad_h, p.pad_w
                        ),
                    )
                    .with_help("at runtime this underflows inside ConvGeom::out_h/out_w"),
                );
                return None;
            }
            let oh = (h + 2 * p.pad_h - p.kernel_h) / p.stride_h + 1;
            let ow = (w + 2 * p.pad_w - p.kernel_w) / p.stride_w + 1;
            Some(vec![vec![n, p.num_output, oh, ow]])
        }
        "Pooling" => {
            let p = match &lp.pool {
                Some(p) => p,
                None => {
                    diags.push(LintDiagnostic::error(
                        "NL0104",
                        Some(name),
                        "pooling layer has no pooling_param".into(),
                    ));
                    return None;
                }
            };
            let (n, c, h, w) = nchw(&bots[0]);
            let (kh, kw) = if p.global_pooling {
                (h, w)
            } else {
                (p.kernel_h, p.kernel_w)
            };
            if p.stride_h == 0 || p.stride_w == 0 || kh == 0 || kw == 0 {
                diags.push(LintDiagnostic::error(
                    "NL0101",
                    Some(name),
                    format!(
                        "invalid geometry: kernel {kh}x{kw}, stride {}x{} (must be >= 1)",
                        p.stride_h, p.stride_w
                    ),
                ));
                return None;
            }
            if h + 2 * p.pad_h < kh || w + 2 * p.pad_w < kw || p.pad_h >= kh || p.pad_w >= kw {
                diags.push(
                    LintDiagnostic::error(
                        "NL0101",
                        Some(name),
                        format!(
                            "kernel {kh}x{kw} incompatible with input {h}x{w} \
                             (pad {}x{}; padding must be smaller than the kernel)",
                            p.pad_h, p.pad_w
                        ),
                    )
                    .with_help("at runtime this underflows inside pooled_dim"),
                );
                return None;
            }
            let ph = pooled_dim(h, kh, p.pad_h, p.stride_h);
            let pw = pooled_dim(w, kw, p.pad_w, p.stride_w);
            Some(vec![vec![n, c, ph, pw]])
        }
        "InnerProduct" => {
            let p = match &lp.inner_product {
                Some(p) => p,
                None => {
                    diags.push(LintDiagnostic::error(
                        "NL0104",
                        Some(name),
                        "inner product layer has no inner_product_param".into(),
                    ));
                    return None;
                }
            };
            if p.num_output == 0 {
                diags.push(LintDiagnostic::error(
                    "NL0104",
                    Some(name),
                    "inner_product_param.num_output must be >= 1".into(),
                ));
                return None;
            }
            let m = dim(&bots[0], 0);
            Some(vec![vec![m, p.num_output]])
        }
        "ReLU" | "Dropout" | "LRN" | "Softmax" => Some(vec![bots[0].clone()]),
        "Split" => {
            if bots.len() != 1 || lp.tops.is_empty() {
                diags.push(LintDiagnostic::error(
                    "NL0104",
                    Some(name),
                    "Split expects 1 bottom and >= 1 tops".into(),
                ));
                return None;
            }
            Some(vec![bots[0].clone(); lp.tops.len()])
        }
        "Concat" => {
            let axis = lp.concat.as_ref().map_or(1, |c| c.axis);
            if axis != 1 {
                diags.push(LintDiagnostic::error(
                    "NL0104",
                    Some(name),
                    format!("Concat supports axis 1 (channels) only, got {axis}"),
                ));
                return None;
            }
            if bots.is_empty() || lp.tops.len() != 1 {
                diags.push(LintDiagnostic::error(
                    "NL0104",
                    Some(name),
                    "Concat expects >= 1 bottoms and exactly 1 top".into(),
                ));
                return None;
            }
            let (n, _, h, w) = nchw(&bots[0]);
            let mut channels = 0;
            for (i, b) in bots.iter().enumerate() {
                let (bn, bc, bh, bw) = nchw(b);
                if bn != n || bh != h || bw != w {
                    diags.push(LintDiagnostic::error(
                        "NL0103",
                        Some(name),
                        format!(
                            "bottom '{}' has shape {}x{}x{}x{}, expected {n}x*x{h}x{w}",
                            lp.bottoms[i], bn, bc, bh, bw
                        ),
                    ));
                    return None;
                }
                channels += bc;
            }
            Some(vec![vec![n, channels, h, w]])
        }
        "SoftmaxWithLoss" | "Accuracy" => {
            let n = dim(&bots[0], 0);
            let labels = count(&bots[1]);
            if labels != n {
                diags.push(LintDiagnostic::error(
                    "NL0103",
                    Some(name),
                    format!(
                        "label bottom '{}' has {labels} elements, scores have batch {n}",
                        lp.bottoms[1]
                    ),
                ));
                return None;
            }
            Some(vec![vec![1]])
        }
        other => {
            diags.push(LintDiagnostic::error(
                "NL0105",
                Some(name),
                format!("unknown layer kind '{other}'"),
            ));
            None
        }
    }
}

fn nchw(shape: &[usize]) -> (usize, usize, usize, usize) {
    (dim(shape, 0), dim(shape, 1), dim(shape, 2), dim(shape, 3))
}

/// Infer the full blob-shape map of `param` at `phase` (optionally
/// re-batched like `Net::reshape_batch(batch)`). Errors if the net has
/// any error-severity geometry/graph finding — use [`super::lint_net`]
/// for diagnostics.
pub fn infer_shapes(
    param: &NetParameter,
    phase: Phase,
    batch: Option<usize>,
) -> anyhow::Result<BTreeMap<String, Vec<usize>>> {
    let layers: Vec<LayerParameter> = param
        .layers_for_phase(phase)
        .into_iter()
        .cloned()
        .collect();
    let with_splits = crate::net::insert_splits(&layers);
    let mut diags = Vec::new();
    let shapes = infer_with_splits(&with_splits, &param.inputs, batch, &mut diags);
    if let Some(d) = diags.iter().find(|d| d.severity == super::Severity::Error) {
        anyhow::bail!("shape inference failed: [{}] {}", d.code, d.message);
    }
    Ok(shapes)
}

/// The static learnable-parameter schema of a (split-inserted) layer
/// list: `((owner layer, slot), element count)` in the same order
/// [`crate::net::Net::share_weights`] exports — the key space
/// [`crate::net::WeightSnapshot::project`] matches on.
pub fn param_schema(
    with_splits: &[LayerParameter],
    shapes: &BTreeMap<String, Vec<usize>>,
) -> Vec<((String, usize), usize)> {
    let mut out = Vec::new();
    for lp in with_splits {
        let bottom = lp.bottoms.first().and_then(|b| shapes.get(b));
        match lp.kind.as_str() {
            "Convolution" => {
                let (p, b) = match (&lp.conv, bottom) {
                    (Some(p), Some(b)) => (p, b),
                    _ => continue,
                };
                let c = dim(b, 1);
                if p.group == 0 || c % p.group != 0 {
                    continue;
                }
                out.push((
                    (lp.name.clone(), 0),
                    p.num_output * (c / p.group) * p.kernel_h * p.kernel_w,
                ));
                if p.bias_term {
                    out.push(((lp.name.clone(), 1), p.num_output));
                }
            }
            "InnerProduct" => {
                let (p, b) = match (&lp.inner_product, bottom) {
                    (Some(p), Some(b)) => (p, b),
                    _ => continue,
                };
                let m = dim(b, 0);
                let k = count(b) / m.max(1);
                out.push(((lp.name.clone(), 0), p.num_output * k));
                if p.bias_term {
                    out.push(((lp.name.clone(), 1), p.num_output));
                }
            }
            _ => {}
        }
    }
    out
}
