//! fecaffe CLI — the conventional Caffe workflow (`caffe train`,
//! `caffe time`) over the FPGA-simulated backend, paper Table 4's
//! "Ease of Use" row.
//!
//! ```text
//! fecaffe train --solver path/to/solver.prototxt [--device fpga|cpu] [--iters N]
//! fecaffe train --net lenet --iters 200            # zoo net + default solver
//! fecaffe train --net lenet --serve 127.0.0.1:8080 # train + serve in one process
//! fecaffe time  --net googlenet --batch 1 --iterations 10
//! fecaffe profile --net lenet --iterations 10      # per-layer / per-kernel sim profile
//! fecaffe zoo                                      # list networks
//! fecaffe export --net lenet                       # print prototxt
//! fecaffe weights --net lenet --out w.fewts        # export a weight snapshot
//! fecaffe lint [--net X] [--deny-warnings] [--format json]  # static analysis
//! fecaffe aot build|verify|clean [--cache-dir D] [--net X]  # AOT plan cache
//! ```

use fecaffe::device::cpu::CpuDevice;
use fecaffe::device::fpga::FpgaSimDevice;
use fecaffe::device::Device;
use fecaffe::layers::LayerTiming;
use fecaffe::net::Net;
use fecaffe::proto::{self, Phase};
use fecaffe::runtime::PjrtBackend;
use fecaffe::serve::{Engine, EngineConfig, HttpConfig, HttpServer, ModelRouter};
use fecaffe::solver::Solver;
use fecaffe::util::cli::{usage, Args, Spec};
use fecaffe::zoo;
use std::sync::Arc;

const SPECS: &[Spec] = &[
    Spec::opt("solver", None, "solver prototxt path"),
    Spec::opt("net", None, "zoo network name or net prototxt path"),
    Spec::opt("device", Some("fpga"), "fpga | cpu"),
    Spec::opt("batch", Some("1"), "train batch size (zoo nets)"),
    Spec::opt("iters", None, "override solver max_iter"),
    Spec::opt("iterations", Some("10"), "timing iterations (time/profile commands)"),
    Spec::opt("snapshot", None, "restore from snapshot before training"),
    Spec::opt(
        "serve",
        None,
        "train command: also serve the net over HTTP at this address, \
         hot-swapping weights into the engine as training progresses",
    ),
    Spec::opt(
        "publish-every",
        Some("25"),
        "publish weights into the serving engine every N iterations (--serve)",
    ),
    Spec::opt("serve-workers", Some("2"), "serving worker replicas (--serve)"),
    Spec::opt("out", Some("weights.fewts"), "weights command: output file"),
    Spec::opt("version", Some("1"), "weights command: snapshot version"),
    Spec::opt("tag", None, "weights command: snapshot tag"),
    Spec::flag("timing-only", "skip numerics, simulate timing only"),
    Spec::flag("no-artifacts", "force native math (skip PJRT artifacts)"),
    Spec::opt("format", Some("text"), "lint command: text | json"),
    Spec::flag("deny-warnings", "lint command: treat warnings as errors"),
    Spec::opt("cache-dir", Some("aot_cache"), "aot command: artifact cache directory"),
];

fn make_device(args: &Args) -> anyhow::Result<Box<dyn Device>> {
    match args.get("device").unwrap_or("fpga") {
        "cpu" => Ok(Box::new(CpuDevice::new())),
        "fpga" => {
            let mut dev = FpgaSimDevice::new();
            if args.has_flag("timing-only") {
                dev.timing_only = true;
            } else if !args.has_flag("no-artifacts") {
                match PjrtBackend::auto() {
                    Some(b) => {
                        eprintln!(
                            "[fecaffe] PJRT artifacts loaded from {:?}",
                            fecaffe::runtime::find_artifacts_dir().unwrap()
                        );
                        dev = dev.with_backend(Box::new(b));
                    }
                    None => eprintln!(
                        "[fecaffe] no artifacts found (run `make artifacts`); using native math"
                    ),
                }
            }
            Ok(Box::new(dev))
        }
        other => anyhow::bail!("unknown device '{other}'"),
    }
}

fn load_net_param(args: &Args) -> anyhow::Result<proto::NetParameter> {
    let name = args
        .get("net")
        .ok_or_else(|| anyhow::anyhow!("--net required"))?;
    let batch = args.get_usize("batch").map_err(anyhow::Error::msg)?;
    if std::path::Path::new(name).is_file() {
        let text = std::fs::read_to_string(name)?;
        proto::parse_net(&text).map_err(anyhow::Error::msg)
    } else {
        zoo::by_name(name, batch)
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let mut dev = make_device(args)?;
    let (netp, mut solverp) = if let Some(path) = args.get("solver") {
        let text = std::fs::read_to_string(path)?;
        let sp = proto::parse_solver(&text).map_err(anyhow::Error::msg)?;
        let netp = if std::path::Path::new(&sp.net).is_file() {
            proto::parse_net(&std::fs::read_to_string(&sp.net)?).map_err(anyhow::Error::msg)?
        } else {
            let batch = args.get_usize("batch").map_err(anyhow::Error::msg)?;
            zoo::by_name(&sp.net, batch)?
        };
        (netp, sp)
    } else {
        let netp = load_net_param(args)?;
        let name = args.get("net").unwrap();
        let sp = zoo::default_solver(name).unwrap_or_default();
        (netp, sp)
    };
    if let Ok(iters) = args.get_usize("iters") {
        solverp.max_iter = iters;
    }
    println!(
        "Training {} on {} with {} (lr {} / {}), {} iterations",
        netp.name,
        dev.kind(),
        solverp.kind.ident(),
        solverp.base_lr,
        solverp.lr_policy,
        solverp.max_iter
    );
    let net = Net::from_param(&netp, Phase::Train, dev.as_mut())?;
    println!(
        "Net: {} layers, {} parameters",
        net.layer_names().len(),
        net.num_parameters()
    );
    let max_iter = solverp.max_iter;
    let mut solver = Solver::new(solverp, net, dev.as_mut())?;
    if let Some(snap) = args.get("snapshot") {
        fecaffe::solver::snapshot::restore(snap, &mut solver, dev.as_mut())?;
        println!("Restored snapshot {} (iter {})", snap, solver.iter);
    }

    // --serve: run the HTTP serving engine in this same process and
    // hot-swap the solver's weights into it as training progresses —
    // the paper's "one framework for training *and* inference" claim,
    // live. Serving workers run on the CPU device so inference never
    // contends for the training device's simulated clock.
    let serving = match args.get("serve") {
        Some(addr) => {
            let model = match args.get("net") {
                Some(n) if !std::path::Path::new(n).is_file() => n.to_string(),
                _ => netp.name.clone(),
            };
            let ecfg = EngineConfig {
                workers: args.get_usize("serve-workers").map_err(anyhow::Error::msg)?,
                ..EngineConfig::default()
            };
            let engine = Engine::new(&netp, ecfg)?;
            let router = Arc::new(ModelRouter::from_engines(vec![(model.clone(), engine)])?);
            // The solver's training counters ride along on the serving
            // surface: `GET /metrics` gains a "training" section (and
            // fecaffe_train_* Prometheus families) while training runs.
            router.attach_training(solver.metrics.clone());
            let server = HttpServer::bind(addr, router.clone(), HttpConfig::default())?;
            println!(
                "[fecaffe] serving '{model}' on http://{} while training \
                 (publish every {} iters)",
                server.local_addr(),
                args.get_usize("publish-every").map_err(anyhow::Error::msg)?
            );
            // Publish the starting weights so the engine serves the
            // solver's parameters (not its own initialization) from the
            // first request on.
            let v = router
                .publish(&model, solver.export_weights(dev.as_mut()))
                .map_err(|e| anyhow::anyhow!("initial weight publish: {e}"))?;
            println!("[fecaffe] published weights v{v} (iter {})", solver.iter);
            Some((router, server, model))
        }
        None => None,
    };

    let t0 = std::time::Instant::now();
    match &serving {
        Some((router, _, model)) => {
            let publish_every =
                args.get_usize("publish-every").map_err(anyhow::Error::msg)?;
            solver.solve_with_publish(dev.as_mut(), max_iter, publish_every, &mut |snap| {
                let tag = snap.tag().unwrap_or("").to_string();
                let v = router
                    .publish(model, snap)
                    .map_err(|e| anyhow::anyhow!("weight publish: {e}"))?;
                println!("[fecaffe] published weights v{v} ({tag})");
                Ok(())
            })?;
        }
        None => solver.solve(dev.as_mut(), max_iter)?,
    }
    let wall = t0.elapsed();
    let tail = solver.loss_history.len().min(10);
    let final_loss: f32 =
        solver.loss_history.iter().rev().take(tail).sum::<f32>() / tail.max(1) as f32;
    println!(
        "Done: {} iterations in {:.1}s wall, final loss ({}-iter mean) {:.4}",
        solver.iter,
        wall.as_secs_f64(),
        tail,
        final_loss
    );
    if let Some(ns) = dev.sim_clock_ns() {
        println!("Simulated device time: {:.3} s", ns as f64 / 1e9);
    }

    if let Some((router, server, model)) = serving {
        // Publish the final weights (unless the last iteration's
        // cadence publish already did), then keep serving the trained
        // model until a client POSTs /admin/shutdown.
        let publish_every = args.get_usize("publish-every").map_err(anyhow::Error::msg)?;
        let last_iter_published =
            publish_every > 0 && solver.iter > 0 && solver.iter % publish_every == 0;
        if !last_iter_published {
            let v = router
                .publish(&model, solver.export_weights(dev.as_mut()))
                .map_err(|e| anyhow::anyhow!("final weight publish: {e}"))?;
            println!("[fecaffe] published final weights v{v} (iter {})", solver.iter);
        }
        println!("[fecaffe] training done; still serving — POST /admin/shutdown to exit");
        server.wait_shutdown();
        println!("[fecaffe] shutdown requested; draining...");
        server.shutdown();
        println!("[fecaffe] drained clean");
    }
    Ok(())
}

/// `fecaffe weights`: export a net's (freshly initialized) parameters
/// as a standalone `FEWSNAP1` weight-snapshot file — the artifact the
/// serving engine's `POST /admin/models/<name>:publish` endpoint loads.
/// The CI smoke test uses this to hot-swap weights into a live server.
fn cmd_weights(args: &Args) -> anyhow::Result<()> {
    let netp = load_net_param(args)?;
    let out = args.get("out").unwrap_or("weights.fewts");
    let version = args.get_usize("version").map_err(anyhow::Error::msg)? as u64;
    let mut dev = CpuDevice::new();
    let mut net = Net::from_param(&netp, Phase::Train, &mut dev)?;
    let mut snap = net.share_weights(&mut dev).with_version(version);
    if let Some(tag) = args.get("tag") {
        snap = snap.with_tag(tag);
    }
    snap.save(out)?;
    println!(
        "Wrote {} (v{}, {} blobs, {} parameters)",
        out,
        snap.version(),
        snap.len(),
        snap.num_parameters()
    );
    Ok(())
}

fn cmd_time(args: &Args) -> anyhow::Result<()> {
    let mut dev = make_device(args)?;
    let netp = load_net_param(args)?;
    let iters = args.get_usize("iterations").map_err(anyhow::Error::msg)?;
    let mut net = Net::from_param(&netp, Phase::Train, dev.as_mut())?;
    println!("*** Benchmark begins ***  ({} iterations, {})", iters, dev.kind());
    let names = net.layer_names();
    let mut fwd = vec![0u64; names.len()];
    let mut bwd = vec![0u64; names.len()];
    for _ in 0..iters {
        let (_, f) = net.forward_timed(dev.as_mut())?;
        let b = net.backward_timed(dev.as_mut())?;
        for i in 0..names.len() {
            fwd[i] += f[i];
            bwd[i] += b[i];
        }
    }
    let mut table = fecaffe::util::table::Table::new(
        &format!("{} per-layer time (ms, avg of {iters})", netp.name),
        &["Layer", "Forward", "Backward"],
    );
    for i in 0..names.len() {
        table.row(&[
            names[i].clone(),
            format!("{:.3}", fwd[i] as f64 / iters as f64 / 1e6),
            format!("{:.3}", bwd[i] as f64 / iters as f64 / 1e6),
        ]);
    }
    let tf: u64 = fwd.iter().sum();
    let tb: u64 = bwd.iter().sum();
    table.row(&[
        "TOTAL".into(),
        format!("{:.3}", tf as f64 / iters as f64 / 1e6),
        format!("{:.3}", tb as f64 / iters as f64 / 1e6),
    ]);
    println!("{}", table.render());
    Ok(())
}

/// `fecaffe profile`: the paper's per-layer / per-kernel-class timing
/// breakdown (Table 2 / Figure 5) from the simulated device. Runs
/// `--iterations` forward passes after one warm-up, accumulates
/// per-layer wall and simulated time through [`Net::forward_traced`],
/// prints both tables, and cross-checks the telescoping invariant: the
/// per-layer simulated times must sum to *exactly* the device's total
/// sim-clock advance (nothing double-counted, nothing unattributed).
fn cmd_profile(args: &Args) -> anyhow::Result<()> {
    let mut dev = make_device(args)?;
    let netp = load_net_param(args)?;
    let iters = args.get_usize("iterations").map_err(anyhow::Error::msg)?.max(1);
    let mut net = Net::from_param(&netp, Phase::Train, dev.as_mut())?;
    // One warm-up pass keeps one-time costs (lazy activation growth,
    // PJRT dispatch setup) out of the profile; then the clocks reset so
    // the measured window starts at sim time 0.
    net.forward(dev.as_mut())?;
    dev.reset_timing();
    let names = net.layer_names();
    let mut kinds: Vec<&'static str> = vec![""; names.len()];
    let mut wall = vec![0u64; names.len()];
    let mut sim = vec![0u64; names.len()];
    for _ in 0..iters {
        net.forward_traced(dev.as_mut(), &mut |t: LayerTiming<'_>| {
            kinds[t.index] = t.kind;
            wall[t.index] += t.wall_ns;
            sim[t.index] += t.sim_ns.unwrap_or(0);
        })?;
    }
    let sim_total: u64 = sim.iter().sum();
    let wall_total: u64 = wall.iter().sum();
    let per_iter = |ns: u64| format!("{:.3}", ns as f64 / iters as f64 / 1e6);
    let share = |ns: u64| {
        if sim_total > 0 {
            format!("{:.1}", ns as f64 * 100.0 / sim_total as f64)
        } else {
            "-".to_string()
        }
    };
    let mut table = fecaffe::util::table::Table::new(
        &format!("{} per-layer profile (avg of {iters} forward passes, {})", netp.name, dev.kind()),
        &["Layer", "Kind", "Wall ms", "Sim ms", "Sim %"],
    );
    for i in 0..names.len() {
        table.row(&[
            names[i].clone(),
            kinds[i].to_string(),
            per_iter(wall[i]),
            per_iter(sim[i]),
            share(sim[i]),
        ]);
    }
    table.row(&[
        "TOTAL".into(),
        "".into(),
        per_iter(wall_total),
        per_iter(sim_total),
        share(sim_total),
    ]);
    println!("{}", table.render());

    let stats = dev.kernel_stats();
    if !stats.is_empty() {
        let mut kt = fecaffe::util::table::Table::new(
            &format!("per-kernel-class simulated time ({iters} forward passes)"),
            &["Class", "Launches", "Total ms", "Mean us"],
        );
        for (label, instances, total_ns) in &stats {
            kt.row(&[
                label.to_string(),
                instances.to_string(),
                format!("{:.3}", *total_ns as f64 / 1e6),
                format!("{:.2}", *total_ns as f64 / (*instances).max(1) as f64 / 1e3),
            ]);
        }
        println!("{}", kt.render());
    }

    if let Some(total) = dev.sim_clock_ns() {
        println!(
            "Simulated device time: {:.3} ms; per-layer sum {:.3} ms",
            total as f64 / 1e6,
            sim_total as f64 / 1e6
        );
        if sim_total != total {
            anyhow::bail!(
                "per-layer sim time ({sim_total} ns) does not telescope to the \
                 device sim clock ({total} ns)"
            );
        }
        println!("Per-layer simulated times telescope exactly to the device clock.");
    }
    Ok(())
}

/// `fecaffe lint`: static analysis of nets (and their solver configs)
/// without building them — graph hygiene, allocation-free shape
/// inference at every serving bucket, in-place aliasing safety,
/// DDR-budget fit against the board model, lr-schedule sanity, and the
/// train→deploy projection check. Engine admission runs the same passes
/// at model load; this command is the ahead-of-time surface (and the CI
/// `lint-nets` leg). With no `--net`, all zoo nets are linted.
fn cmd_lint(args: &Args) -> anyhow::Result<()> {
    use fecaffe::netlint::{lint_net, LintOptions, LintReport};
    use fecaffe::runtime::plan::{serve_bucket_cap, serve_buckets};

    let targets: Vec<String> = match args.get("net") {
        Some(n) => vec![n.to_string()],
        None => zoo::NETWORKS.iter().map(|n| n.to_string()).collect(),
    };
    let mut reports: Vec<LintReport> = Vec::new();
    for t in &targets {
        let (param, zoo_name) = if std::path::Path::new(t).is_file() {
            let text = std::fs::read_to_string(t)?;
            (proto::parse_net(&text).map_err(anyhow::Error::msg)?, None)
        } else {
            let batch = args.get_usize("batch").map_err(anyhow::Error::msg)?;
            (zoo::by_name(t, batch)?, Some(t.as_str()))
        };
        let cap = serve_bucket_cap(zoo_name.unwrap_or(param.name.as_str()));
        let deploy_opts = |buckets: Vec<usize>| LintOptions {
            phase: Phase::Test,
            buckets,
            forward_only: true,
            ..Default::default()
        };
        if param.inputs.is_empty() {
            // train_val style: lint the training graph (with its solver
            // schedule and the train→deploy projection), then the
            // derived deploy net at every serving bucket.
            let solver = zoo_name.and_then(|n| zoo::default_solver(n).ok());
            reports.push(lint_net(
                &param,
                &LintOptions {
                    phase: Phase::Train,
                    solver,
                    check_deploy_projection: true,
                    ..Default::default()
                },
            ));
            // A failed deploy derivation is already reported as NL0411.
            if let Ok(dep) = zoo::deploy(&param, 1) {
                reports.push(lint_net(&dep.param, &deploy_opts(serve_buckets(cap))));
            }
        } else {
            reports.push(lint_net(&param, &deploy_opts(serve_buckets(cap))));
        }
    }

    let errors: usize = reports.iter().map(|r| r.error_count()).sum();
    let warnings: usize = reports.iter().map(|r| r.warning_count()).sum();
    match args.get("format").unwrap_or("text") {
        "json" => {
            let arr = fecaffe::util::json::Json::arr(reports.iter().map(|r| r.render_json()));
            println!("{}", arr.to_pretty());
        }
        "text" => {
            for r in &reports {
                print!("{}", r.render_text());
            }
            println!(
                "netlint: {} net(s) checked: {errors} error(s), {warnings} warning(s)",
                reports.len()
            );
        }
        other => anyhow::bail!("unknown --format '{other}' (text | json)"),
    }
    if errors > 0 || (warnings > 0 && args.has_flag("deny-warnings")) {
        anyhow::bail!(
            "lint failed: {errors} error(s), {warnings} warning(s){}",
            if errors == 0 { " rejected by --deny-warnings" } else { "" }
        );
    }
    Ok(())
}

/// `fecaffe aot`: materialize, check or delete the content-addressed
/// AOT plan cache the serving engine cold-boots from.
///
/// * `build`  — record every (net × serving bucket) deploy forward and
///   write one `FEPLAN1` container each, plus `MANIFEST.sha256`.
///   Deterministic: two builds of the same commit are byte-identical
///   (the CI `repro` leg asserts this).
/// * `verify` — re-derive every content key from the live zoo and check
///   the manifest digests, container parses and plan envelopes.
/// * `clean`  — delete the cache directory (refuses directories that
///   don't look like a cache).
fn cmd_aot(args: &Args) -> anyhow::Result<()> {
    use fecaffe::aot;
    let dir = std::path::PathBuf::from(args.get("cache-dir").unwrap_or("aot_cache"));
    let matrix = fecaffe::runtime::plan::serve_matrix();
    let nets: Vec<&str> = match args.get("net") {
        Some(n) => {
            // `name[@precision]`: lenet@int8 caches the int8 serving
            // variant (own content keys, `.int8.feplan` siblings).
            let (base, _) = fecaffe::quant::split_model_name(n)?;
            let known = matrix.iter().any(|(name, _)| *name == base);
            anyhow::ensure!(known, "--net '{n}' is not a zoo network");
            vec![n]
        }
        None => matrix.iter().map(|(name, _)| *name).collect(),
    };
    match args.positional.get(1).map(|s| s.as_str()).unwrap_or("") {
        "build" => {
            let t0 = std::time::Instant::now();
            let report = aot::build_matrix(&dir, &nets)?;
            println!(
                "aot build: {} container(s), {} plan(s), {} net(s) -> {} in {:.2}s",
                report.files.len(),
                report.plan_count,
                nets.len(),
                dir.display(),
                t0.elapsed().as_secs_f64()
            );
            Ok(())
        }
        "verify" => {
            let t0 = std::time::Instant::now();
            let report = aot::verify_matrix(&dir, &nets)?;
            println!(
                "aot verify: {} container(s) OK ({} plan(s), {} KiB) in {} in {:.2}s",
                report.files,
                report.plan_count,
                report.total_bytes / 1024,
                dir.display(),
                t0.elapsed().as_secs_f64()
            );
            Ok(())
        }
        "clean" => {
            if aot::clean(&dir)? {
                println!("aot clean: removed {}", dir.display());
            } else {
                println!("aot clean: {} does not exist", dir.display());
            }
            Ok(())
        }
        other => anyhow::bail!("unknown aot subcommand '{other}' (build | verify | clean)"),
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv, SPECS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", usage("fecaffe", "FeCaffe coordinator", SPECS));
            std::process::exit(2);
        }
    };
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "train" => cmd_train(&args),
        "time" => cmd_time(&args),
        "profile" => cmd_profile(&args),
        "weights" => cmd_weights(&args),
        "lint" => cmd_lint(&args),
        "aot" => cmd_aot(&args),
        "zoo" => {
            for n in zoo::NETWORKS {
                println!("{n}");
            }
            Ok(())
        }
        "export" => load_net_param(&args).map(|p| {
            print!("{}", proto::emit::emit_net(&p));
        }),
        _ => {
            println!(
                "{}",
                usage(
                    "fecaffe <train|time|profile|zoo|export|weights|lint|aot>",
                    "FeCaffe: FPGA-enabled Caffe (simulated Stratix 10 + PJRT AOT kernels)",
                    SPECS
                )
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
