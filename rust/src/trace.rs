//! Timeline export — the stand-in for the paper's VTune / OpenCL-profiler
//! views (Figures 4 and 5).
//!
//! Renderers over [`crate::device::fpga::profiler::Span`]s:
//! * [`chrome_trace`] — chrome-trace JSON (open in `chrome://tracing` /
//!   Perfetto) with one track per lane, mirroring Figure 4's CPU-green
//!   vs FPGA-pink lanes;
//! * [`chrome_trace_batches`] — the same, with one *process* group per
//!   sampled serving batch (`GET /admin/trace` uses this: each batch's
//!   queue/host/layer/pcie/fpga-kernel lanes land under its own named
//!   group in the Perfetto track list);
//! * [`ascii_timeline`] — a fixed-width ASCII timeline for terminals
//!   and EXPERIMENTS.md.

use crate::device::fpga::profiler::Span;
use crate::util::json::Json;
use std::collections::BTreeSet;

/// Stable chrome-trace thread id per lane. The mapping is part of the
/// trace format: saved traces diff cleanly across runs, and tests (or
/// external tooling) can rely on it.
pub fn lane_tid(lane: &str) -> u32 {
    match lane {
        "host" => 0,
        "pcie" => 1,
        "fpga-kernel" => 2,
        "queue" => 3,
        "layer" => 4,
        _ => 5,
    }
}

/// Append one batch's X events plus thread-name metadata for every lane
/// actually present (no phantom empty tracks).
fn push_batch_events(events: &mut Vec<Json>, pid: u32, spans: &[Span]) {
    let mut lanes: BTreeSet<(u32, &str)> = BTreeSet::new();
    for s in spans {
        lanes.insert((lane_tid(s.lane), s.lane));
        let mut e = Json::obj();
        e.set("name", Json::str(s.name.clone()))
            .set("ph", Json::str("X"))
            .set("pid", Json::num(pid))
            .set("tid", Json::num(lane_tid(s.lane)))
            .set("ts", Json::num(s.start_ns as f64 / 1e3))
            .set("dur", Json::num((s.dur_ns.max(1)) as f64 / 1e3))
            .set("cat", Json::str(s.lane));
        events.push(e);
    }
    for (tid, lane) in lanes {
        let mut args = Json::obj();
        args.set("name", Json::str(lane));
        let mut e = Json::obj();
        e.set("name", Json::str("thread_name"))
            .set("ph", Json::str("M"))
            .set("pid", Json::num(pid))
            .set("tid", Json::num(tid))
            .set("args", args);
        events.push(e);
    }
}

/// Spans → chrome-trace JSON ("traceEvents" array of X events).
pub fn chrome_trace(spans: &[Span]) -> String {
    let mut events = Vec::new();
    push_batch_events(&mut events, 1, spans);
    let mut root = Json::obj();
    root.set("traceEvents", Json::Arr(events));
    root.to_string()
}

/// Labelled span sets → one chrome-trace JSON document with a named
/// process group per entry (pid = position + 1). This is the shape
/// `/admin/trace` serves: one group per sampled batch, each holding
/// that batch's full host + device timeline.
pub fn chrome_trace_batches(batches: &[(String, Vec<Span>)]) -> String {
    let mut events = Vec::new();
    for (i, (label, spans)) in batches.iter().enumerate() {
        let pid = i as u32 + 1;
        let mut args = Json::obj();
        args.set("name", Json::str(label.clone()));
        let mut e = Json::obj();
        e.set("name", Json::str("process_name"))
            .set("ph", Json::str("M"))
            .set("pid", Json::num(pid))
            .set("tid", Json::num(0))
            .set("args", args);
        events.push(e);
        push_batch_events(&mut events, pid, spans);
    }
    let mut root = Json::obj();
    root.set("traceEvents", Json::Arr(events));
    root.to_string()
}

/// Spans → fixed-width ASCII timeline (Figure 4 in a terminal).
/// `cols` character cells cover the full [0, end] range. The device
/// lanes always render (so empty traces still show the frame); any
/// other lane present in the spans gets a row in first-seen order.
pub fn ascii_timeline(spans: &[Span], cols: usize) -> String {
    let end = spans
        .iter()
        .map(|s| s.start_ns + s.dur_ns)
        .max()
        .unwrap_or(1)
        .max(1);
    let mut out = String::new();
    let mut lanes: Vec<&str> = vec!["pcie", "fpga-kernel"];
    for s in spans {
        if !lanes.contains(&s.lane) {
            lanes.push(s.lane);
        }
    }
    for lane in lanes {
        let mut row = vec![b'.'; cols];
        for s in spans.iter().filter(|s| s.lane == lane) {
            let a = (u128::from(s.start_ns) * cols as u128 / u128::from(end)) as usize;
            let b = ((u128::from(s.start_ns + s.dur_ns) * cols as u128 + u128::from(end) - 1)
                / u128::from(end)) as usize;
            let glyph = s.name.bytes().next().unwrap_or(b'#');
            for c in row.iter_mut().take(b.min(cols)).skip(a) {
                *c = glyph;
            }
        }
        out.push_str(&format!(
            "{:<12} |{}|\n",
            lane,
            String::from_utf8_lossy(&row)
        ));
    }
    out.push_str(&format!(
        "{:<12}  0 {:>width$.3} ms\n",
        "",
        end as f64 / 1e6,
        width = cols.saturating_sub(2)
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spans() -> Vec<Span> {
        vec![
            Span { lane: "pcie", name: "Write_Buffer".into(), start_ns: 0, dur_ns: 100 },
            Span { lane: "fpga-kernel", name: "Gemm".into(), start_ns: 100, dur_ns: 300 },
            Span { lane: "fpga-kernel", name: "ReLU_F".into(), start_ns: 400, dur_ns: 50 },
        ]
    }

    #[test]
    fn chrome_trace_is_valid_json_with_all_events() {
        let text = chrome_trace(&spans());
        let v = Json::parse(&text).unwrap();
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        // 3 spans + thread_name metadata for the 2 lanes present.
        assert_eq!(events.len(), 5);
        let first = &events[0];
        assert_eq!(first.get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(first.get("ts").unwrap().as_f64().unwrap(), 0.0);
    }

    #[test]
    fn lanes_map_to_stable_tids() {
        // The mapping is frozen: traces saved from different runs (and
        // the integration tests) rely on these exact ids.
        let expect = [("host", 0), ("pcie", 1), ("fpga-kernel", 2), ("queue", 3), ("layer", 4)];
        for (lane, tid) in expect {
            assert_eq!(lane_tid(lane), tid, "{lane}");
        }
        assert_eq!(lane_tid("anything-else"), 5);
        let text = chrome_trace(&spans());
        let v = Json::parse(&text).unwrap();
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        for e in events.iter().filter(|e| e.get("cat").is_some()) {
            let cat = e.get("cat").unwrap().as_str().unwrap().to_string();
            let tid = e.get("tid").unwrap().as_usize().unwrap() as u32;
            assert_eq!(tid, lane_tid(&cat));
        }
    }

    #[test]
    fn batched_trace_groups_by_pid_with_process_names() {
        let batches = vec![
            ("lenet batch 0 (3/4 rows)".to_string(), spans()),
            ("lenet batch 8 (1/1 rows)".to_string(), spans()[..1].to_vec()),
        ];
        let text = chrome_trace_batches(&batches);
        let v = Json::parse(&text).unwrap();
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        // Batch 1: process_name + 3 spans + 2 lane metas;
        // batch 2: process_name + 1 span + 1 lane meta.
        assert_eq!(events.len(), 9);
        let names: Vec<&str> = events
            .iter()
            .filter(|e| e.get("name").unwrap().as_str() == Some("process_name"))
            .map(|e| e.get("args").unwrap().get("name").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(names, vec!["lenet batch 0 (3/4 rows)", "lenet batch 8 (1/1 rows)"]);
        // Every X event of the second batch carries pid 2.
        let pids: BTreeSet<usize> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .map(|e| e.get("pid").unwrap().as_usize().unwrap())
            .collect();
        assert_eq!(pids, BTreeSet::from([1, 2]));
    }

    #[test]
    fn ascii_timeline_shows_lanes() {
        let text = ascii_timeline(&spans(), 40);
        assert!(text.contains("pcie"));
        assert!(text.contains("fpga-kernel"));
        // gemm glyph appears
        assert!(text.contains('G'));
        assert!(text.contains('W'));
    }

    #[test]
    fn ascii_timeline_stays_fixed_width_with_overlaps_and_extra_lanes() {
        // Overlapping spans on one lane plus host-side lanes: every row
        // must still be exactly `cols` cells between its delimiters.
        let spans = vec![
            Span { lane: "fpga-kernel", name: "Gemm".into(), start_ns: 0, dur_ns: 900 },
            Span { lane: "fpga-kernel", name: "ReLU_F".into(), start_ns: 300, dur_ns: 900 },
            Span { lane: "queue", name: "queue-wait".into(), start_ns: 0, dur_ns: 400 },
            Span { lane: "layer", name: "conv1".into(), start_ns: 500, dur_ns: 700 },
        ];
        let cols = 32;
        let text = ascii_timeline(&spans, cols);
        for lane in ["pcie", "fpga-kernel", "queue", "layer"] {
            assert!(text.contains(lane), "missing lane {lane}");
        }
        let rows: Vec<&str> = text.lines().filter(|l| l.contains('|')).collect();
        assert_eq!(rows.len(), 4);
        for row in rows {
            let inner = row.split('|').nth(1).unwrap();
            assert_eq!(inner.len(), cols, "row not fixed-width: {row}");
        }
        // The overlap region renders the later span's glyph, clamped in
        // bounds — no row ever grows past `cols`.
        assert!(text.contains('R'));
    }

    #[test]
    fn empty_spans_dont_panic() {
        let text = ascii_timeline(&[], 10);
        assert!(text.contains("pcie"));
        let json = chrome_trace(&[]);
        assert!(Json::parse(&json).is_ok());
        let json = chrome_trace_batches(&[]);
        assert!(Json::parse(&json).is_ok());
    }
}
