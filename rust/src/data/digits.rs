//! Procedural digit renderer — the learnable MNIST stand-in.
//!
//! Each digit is a 5×7 bitmap glyph upscaled ~3× into the 28×28 canvas
//! with a random sub-pixel offset, per-sample intensity jitter and
//! additive noise. Classes are visually distinct but overlapping enough
//! that the loss curve behaves like MNIST's.

use super::DataSource;
use crate::util::prng::Pcg32;

/// 5×7 glyphs, row-major, '1' = ink.
const GLYPHS: [[u8; 35]; 10] = [
    // 0
    [0,1,1,1,0, 1,0,0,0,1, 1,0,0,1,1, 1,0,1,0,1, 1,1,0,0,1, 1,0,0,0,1, 0,1,1,1,0],
    // 1
    [0,0,1,0,0, 0,1,1,0,0, 0,0,1,0,0, 0,0,1,0,0, 0,0,1,0,0, 0,0,1,0,0, 0,1,1,1,0],
    // 2
    [0,1,1,1,0, 1,0,0,0,1, 0,0,0,0,1, 0,0,1,1,0, 0,1,0,0,0, 1,0,0,0,0, 1,1,1,1,1],
    // 3
    [0,1,1,1,0, 1,0,0,0,1, 0,0,0,0,1, 0,0,1,1,0, 0,0,0,0,1, 1,0,0,0,1, 0,1,1,1,0],
    // 4
    [0,0,0,1,0, 0,0,1,1,0, 0,1,0,1,0, 1,0,0,1,0, 1,1,1,1,1, 0,0,0,1,0, 0,0,0,1,0],
    // 5
    [1,1,1,1,1, 1,0,0,0,0, 1,1,1,1,0, 0,0,0,0,1, 0,0,0,0,1, 1,0,0,0,1, 0,1,1,1,0],
    // 6
    [0,0,1,1,0, 0,1,0,0,0, 1,0,0,0,0, 1,1,1,1,0, 1,0,0,0,1, 1,0,0,0,1, 0,1,1,1,0],
    // 7
    [1,1,1,1,1, 0,0,0,0,1, 0,0,0,1,0, 0,0,1,0,0, 0,1,0,0,0, 0,1,0,0,0, 0,1,0,0,0],
    // 8
    [0,1,1,1,0, 1,0,0,0,1, 1,0,0,0,1, 0,1,1,1,0, 1,0,0,0,1, 1,0,0,0,1, 0,1,1,1,0],
    // 9
    [0,1,1,1,0, 1,0,0,0,1, 1,0,0,0,1, 0,1,1,1,1, 0,0,0,0,1, 0,0,0,1,0, 0,1,1,0,0],
];

pub struct Digits {
    height: usize,
    width: usize,
    num_classes: usize,
}

impl Digits {
    pub fn new(height: usize, width: usize) -> Digits {
        Digits::with_classes(height, width, 10)
    }

    pub fn with_classes(height: usize, width: usize, num_classes: usize) -> Digits {
        Digits { height, width, num_classes: num_classes.clamp(2, 10) }
    }

    /// Render `digit` with the given jitter parameters (deterministic).
    pub fn render(
        &self,
        digit: usize,
        dx: f32,
        dy: f32,
        scale: f32,
        intensity: f32,
    ) -> Vec<f32> {
        let glyph = &GLYPHS[digit % 10];
        let (h, w) = (self.height, self.width);
        let mut img = vec![0.0f32; h * w];
        // Map canvas pixel -> glyph cell via bilinear sampling of the 5x7
        // bitmap placed centered with jitter.
        let gw = 5.0 * scale;
        let gh = 7.0 * scale;
        let x0 = (w as f32 - gw) / 2.0 + dx;
        let y0 = (h as f32 - gh) / 2.0 + dy;
        for y in 0..h {
            for x in 0..w {
                let gx = (x as f32 - x0) / scale;
                let gy = (y as f32 - y0) / scale;
                if gx >= 0.0 && gx < 5.0 && gy >= 0.0 && gy < 7.0 {
                    let (cx, cy) = (gx as usize, gy as usize);
                    if glyph[cy * 5 + cx] == 1 {
                        img[y * w + x] = intensity;
                    }
                }
            }
        }
        img
    }
}

impl DataSource for Digits {
    fn shape(&self) -> (usize, usize, usize) {
        (1, self.height, self.width)
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn sample(&self, rng: &mut Pcg32) -> (Vec<f32>, usize) {
        let digit = rng.below(self.num_classes as u32) as usize;
        let dx = rng.uniform(-3.0, 3.0);
        let dy = rng.uniform(-3.0, 3.0);
        let scale = rng.uniform(2.6, 3.4);
        let intensity = rng.uniform(0.7, 1.0);
        let mut img = self.render(digit, dx, dy, scale, intensity);
        for v in img.iter_mut() {
            *v += rng.gaussian(0.0, 0.05);
        }
        (img, digit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_ink_inside_canvas() {
        let d = Digits::new(28, 28);
        for digit in 0..10 {
            let img = d.render(digit, 0.0, 0.0, 3.0, 1.0);
            let ink: f32 = img.iter().sum();
            assert!(ink > 10.0, "digit {digit} has no ink");
        }
    }

    #[test]
    fn digits_are_distinct() {
        let d = Digits::new(28, 28);
        let one = d.render(1, 0.0, 0.0, 3.0, 1.0);
        let eight = d.render(8, 0.0, 0.0, 3.0, 1.0);
        let diff: f32 = one
            .iter()
            .zip(eight.iter())
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 20.0);
    }

    #[test]
    fn sampling_is_label_consistent_and_jittered() {
        let d = Digits::new(28, 28);
        let mut rng = Pcg32::new(9);
        let (img1, l1) = d.sample(&mut rng);
        let (img2, _) = d.sample(&mut rng);
        assert!(l1 < 10);
        assert_ne!(img1, img2);
    }
}
