//! Intra-op parallelism substrate: a std-only scoped thread pool with a
//! `parallel_for` primitive and a process-wide thread *budget*.
//!
//! The paper's kernel-time breakdown (Table 3) is dominated by GEMM/GEMV,
//! and the CPU fallback device is the reference every FPGA-sim and serve
//! number is judged against — so the native math library shards its block
//! loops across this pool. Design constraints, in order:
//!
//! 1. **Zero dependencies** — plain `Mutex`/`Condvar` workers, no
//!    work-stealing deques, no channels. One job is broadcast at a time;
//!    workers race on an atomic chunk counter for load balance.
//! 2. **Deterministic numerics** — `parallel_for` hands out *chunks of the
//!    index space*, never partial sums. Every output element is written by
//!    exactly one task, so results are bit-identical at any thread count
//!    (reductions stay serial in the math layer for the same reason).
//! 3. **A shared budget** — serve's inter-op workers and intra-op GEMM
//!    threads must not oversubscribe the machine. The process-wide width
//!    is [`default_threads`] (`FECAFFE_THREADS` env, else
//!    `available_parallelism`); each thread can additionally be capped
//!    with [`set_intra_op`] / [`with_intra_op`], which is how
//!    `serve::Engine` splits the machine across its worker pool and how
//!    `Device::with_intra_op` scopes a per-device cap around kernel
//!    execution.
//! 4. **Never deadlock, never block on a busy pool** — the pool runs one
//!    broadcast at a time; a competing (or nested) `parallel_for` simply
//!    runs its body serially on the calling thread instead of waiting.
//!    Consequence worth knowing: when several inter-op threads (e.g.
//!    serve workers) fan out at the same instant, only one wins the
//!    broadcast and the rest run that kernel serially — the intra-op
//!    budget is a *cap*, not a guarantee. That's the right trade here:
//!    concurrent inter-op workers already occupy the cores, and the cap
//!    still prevents oversubscription; intra-op fan-out pays off most
//!    for training and low-worker-count serving, where one thread owns
//!    the hot path.
//!
//! The pool is lazily spawned on first use and lives for the process.

use std::cell::Cell;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, Once, OnceLock};

/// Elementwise ops below this many elements aren't worth a pool wakeup.
pub const GRAIN_ELEMWISE: usize = 8192;

// ---------------------------------------------------------------------------
// Thread budget
// ---------------------------------------------------------------------------

/// Process-wide parallelism width: `FECAFFE_THREADS` if set to a positive
/// integer, else `std::thread::available_parallelism()`. Decided once.
pub fn default_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(s) = std::env::var("FECAFFE_THREADS") {
            if let Ok(n) = s.trim().parse::<usize>() {
                if n >= 1 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

thread_local! {
    /// Per-thread intra-op cap; 0 = uncapped (use the process default).
    static INTRA_OP: Cell<usize> = const { Cell::new(0) };
}

/// Cap the calling thread's intra-op parallelism at `limit` threads
/// (0 clears the cap). A serve worker calls this once at startup with its
/// share of the machine; every math kernel invoked from that thread then
/// fans out at most `limit` wide.
pub fn set_intra_op(limit: usize) {
    INTRA_OP.with(|c| c.set(limit));
}

/// The calling thread's intra-op cap (0 = uncapped).
pub fn intra_op() -> usize {
    INTRA_OP.with(|c| c.get())
}

/// Run `f` with the calling thread's intra-op cap tightened to `limit`
/// (no-op when `limit == 0`; an existing tighter cap wins). Restores the
/// previous cap on exit, including on panic.
pub fn with_intra_op<R>(limit: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            set_intra_op(self.0);
        }
    }
    let prev = intra_op();
    let _restore = Restore(prev);
    let eff = match (prev, limit) {
        (p, 0) => p,
        (0, l) => l,
        (p, l) => p.min(l),
    };
    set_intra_op(eff);
    f()
}

/// Effective parallelism for work submitted from the calling thread.
pub fn current_threads() -> usize {
    let cap = intra_op();
    if cap == 0 {
        default_threads()
    } else {
        cap.min(default_threads())
    }
}

// ---------------------------------------------------------------------------
// The pool
// ---------------------------------------------------------------------------

/// Type-erased pointer to a `&(dyn Fn() + Sync)` that lives on the
/// broadcasting thread's stack. Only valid until the broadcast returns,
/// which `broadcast_and_join` enforces by joining every claimant.
#[derive(Clone, Copy)]
struct Task {
    ptr: *const (dyn Fn() + Sync),
}
// Safety: the pointee is Sync, and the broadcast protocol guarantees it
// outlives every worker's use of it.
unsafe impl Send for Task {}

struct Slot {
    /// Monotonic job id; a worker runs each epoch at most once.
    epoch: u64,
    /// Worker claims remaining for the current epoch.
    claims: usize,
    /// Workers currently inside the task body.
    running: usize,
    task: Option<Task>,
    /// A worker's task body panicked during the current epoch.
    panicked: bool,
}

pub struct ThreadPool {
    slot: Mutex<Slot>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// Serializes broadcasts. Competing callers don't wait — they run
    /// their body serially — which also makes nested `parallel_for` safe.
    submit: Mutex<()>,
    /// Helper threads (the caller is the +1th lane).
    workers: usize,
}

static POOL: OnceLock<ThreadPool> = OnceLock::new();
static SPAWN: Once = Once::new();

/// The process-wide pool, spawned on first use with
/// `default_threads() - 1` helper threads.
pub fn global() -> &'static ThreadPool {
    let pool = POOL.get_or_init(|| ThreadPool {
        slot: Mutex::new(Slot {
            epoch: 0,
            claims: 0,
            running: 0,
            task: None,
            panicked: false,
        }),
        work_cv: Condvar::new(),
        done_cv: Condvar::new(),
        submit: Mutex::new(()),
        workers: default_threads().saturating_sub(1),
    });
    SPAWN.call_once(|| {
        for i in 0..pool.workers {
            std::thread::Builder::new()
                .name(format!("fecaffe-pool-{i}"))
                .spawn(move || worker_loop(pool))
                .expect("spawn pool worker");
        }
    });
    pool
}

fn worker_loop(pool: &'static ThreadPool) {
    let mut seen = 0u64;
    loop {
        let task = {
            let mut s = pool.slot.lock().unwrap();
            loop {
                if s.epoch != seen {
                    seen = s.epoch;
                    if s.claims > 0 {
                        s.claims -= 1;
                        s.running += 1;
                        break s.task.expect("task set while claims > 0");
                    }
                    // Epoch already fully claimed by faster siblings.
                }
                s = pool.work_cv.wait(s).unwrap();
            }
        };
        // Run outside the lock; a panicking body must not wedge the pool.
        let result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe { (&*task.ptr)() }));
        let mut s = pool.slot.lock().unwrap();
        s.running -= 1;
        if result.is_err() {
            s.panicked = true;
        }
        if s.claims == 0 && s.running == 0 {
            pool.done_cv.notify_all();
        }
    }
}

impl ThreadPool {
    /// Run `task` on up to `claims` pool workers *and* the calling thread,
    /// returning once every participant has finished. Panics (in any
    /// participant) propagate to the caller after the join, so the task's
    /// borrows never dangle.
    fn broadcast_and_join(&self, claims: usize, task: &(dyn Fn() + Sync)) {
        let claims = claims.min(self.workers);
        if claims == 0 {
            task();
            return;
        }
        {
            let mut s = self.slot.lock().unwrap();
            s.epoch += 1;
            s.claims = claims;
            s.task = Some(Task { ptr: task as *const (dyn Fn() + Sync) });
            self.work_cv.notify_all();
        }
        let caller = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task));
        let panicked = {
            let mut s = self.slot.lock().unwrap();
            while s.claims > 0 || s.running > 0 {
                s = self.done_cv.wait(s).unwrap();
            }
            s.task = None;
            std::mem::replace(&mut s.panicked, false)
        };
        if let Err(p) = caller {
            std::panic::resume_unwind(p);
        }
        if panicked {
            panic!("fecaffe thread pool: a parallel task panicked");
        }
    }
}

// ---------------------------------------------------------------------------
// parallel_for
// ---------------------------------------------------------------------------

/// Apply `body` over `range`, split into contiguous chunks of at least
/// `grain` indices, sharded across the pool plus the calling thread.
///
/// Guarantees:
/// * every index is covered by exactly one `body` call (chunk boundaries
///   may differ with the thread budget, so `body` must be independent
///   per *index*, not per chunk — write elements, don't fold partial
///   sums across a chunk into shared state);
/// * the call returns only after every `body` invocation has finished;
/// * runs entirely on the calling thread when the work is small, the
///   effective budget is 1, or the pool is busy with another broadcast.
pub fn parallel_for<F>(range: Range<usize>, grain: usize, body: F)
where
    F: Fn(Range<usize>) + Sync,
{
    let n = range.end.saturating_sub(range.start);
    if n == 0 {
        return;
    }
    let grain = grain.max(1);
    let t = current_threads().min(n.div_ceil(grain)).max(1);
    if t == 1 {
        body(range);
        return;
    }
    let pool = global();
    let _submit = match pool.submit.try_lock() {
        Ok(g) => g,
        // A previous broadcast panicked out through the guard; the lock
        // state itself is fine — keep using it.
        Err(std::sync::TryLockError::Poisoned(p)) => p.into_inner(),
        Err(std::sync::TryLockError::WouldBlock) => {
            // Pool busy (another broadcast, or we're nested inside one):
            // degrade to serial rather than wait.
            body(range);
            return;
        }
    };
    // A few chunks per lane for load balance, never smaller than grain.
    // Chunk boundaries depend only on (n, grain, t) — and every chunk is
    // processed independently — so numerics don't depend on which thread
    // runs which chunk.
    let chunk = grain.max(n.div_ceil(t * 4));
    let nchunks = n.div_ceil(chunk);
    let next = AtomicUsize::new(0);
    let start = range.start;
    let end = range.end;
    let work = move || loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= nchunks {
            break;
        }
        let s = start + i * chunk;
        let e = (s + chunk).min(end);
        body(s..e);
    };
    pool.broadcast_and_join(t - 1, &work);
}

// ---------------------------------------------------------------------------
// Shared-slice helpers
// ---------------------------------------------------------------------------

/// A raw mutable pointer that may cross threads. Used by the math kernels
/// to hand each `parallel_for` chunk its own *disjoint* window of an
/// output slice; the caller is responsible for disjointness.
pub struct SendPtr<T>(*mut T);

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(ptr: *mut T) -> SendPtr<T> {
        SendPtr(ptr)
    }

    /// Reborrow `len` elements starting at `offset`.
    ///
    /// # Safety
    /// `offset..offset + len` must lie inside the original allocation and
    /// must not overlap any window handed to a concurrently running task.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, offset: usize, len: usize) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(offset), len)
    }
}

/// Split `data` into contiguous chunks of at least `grain` elements and
/// apply `body(offset, chunk)` to each, in parallel. Disjointness is by
/// construction, so this is the safe front door for elementwise kernels.
pub fn parallel_chunks_mut<T, F>(data: &mut [T], grain: usize, body: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let len = data.len();
    let ptr = SendPtr::new(data.as_mut_ptr());
    parallel_for(0..len, grain, |r| {
        let off = r.start;
        let chunk = unsafe { ptr.slice(off, r.len()) };
        body(off, chunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn covers_every_index_exactly_once() {
        for (n, grain) in [(0usize, 1usize), (1, 1), (7, 100), (1000, 1), (4096, 64)] {
            let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
            parallel_for(10..10 + n, grain, |r| {
                for i in r {
                    hits[i - 10].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "n={n} grain={grain}"
            );
        }
    }

    #[test]
    fn chunks_mut_writes_disjoint_windows() {
        let mut data = vec![0usize; 10_000];
        parallel_chunks_mut(&mut data, 7, |off, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = off + i;
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i);
        }
    }

    #[test]
    fn intra_op_cap_scopes_and_restores() {
        assert_eq!(intra_op(), 0);
        with_intra_op(2, || {
            assert_eq!(intra_op(), 2);
            with_intra_op(8, || assert_eq!(intra_op(), 2, "tighter cap wins"));
            with_intra_op(1, || assert_eq!(intra_op(), 1));
            assert_eq!(intra_op(), 2);
        });
        assert_eq!(intra_op(), 0);
        assert!(current_threads() >= 1);
    }

    #[test]
    fn nested_parallel_for_degrades_to_serial() {
        let total = AtomicU32::new(0);
        parallel_for(0..64, 1, |outer| {
            // Nested call: must complete (serially) without deadlock.
            parallel_for(0..outer.len(), 1, |inner| {
                total.fetch_add(inner.len() as u32, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn pool_survives_a_panicking_task() {
        let caught = std::panic::catch_unwind(|| {
            parallel_for(0..1024, 1, |r| {
                if r.contains(&512) {
                    panic!("boom");
                }
            });
        });
        assert!(caught.is_err());
        // Pool still serviceable afterwards.
        let total = AtomicU32::new(0);
        parallel_for(0..100, 1, |r| {
            total.fetch_add(r.len() as u32, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 100);
    }
}
