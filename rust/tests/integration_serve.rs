//! Integration: the serving engine's core guarantees.
//!
//! * batch coalescing is *correct*: N single-sample requests served as
//!   one batched forward produce bit-identical outputs to sequential
//!   single-sample forwards on a batch-1 replica with the same weights;
//! * graceful shutdown drains the queue: every admitted request gets a
//!   response, none are lost;
//! * admission/lifecycle errors surface as typed `ServeError`s.

use fecaffe::device::cpu::CpuDevice;
use fecaffe::net::Net;
use fecaffe::proto::Phase;
use fecaffe::serve::{DeviceKind, Engine, EngineConfig, ServeError};
use fecaffe::util::prng::Pcg32;
use fecaffe::zoo;
use std::time::Duration;

fn lenet_engine(workers: usize, max_batch: usize, linger: Duration, cap: usize) -> Engine {
    let param = zoo::by_name("lenet", 1).unwrap();
    Engine::new(
        &param,
        EngineConfig {
            workers,
            max_batch,
            max_linger: linger,
            queue_capacity: cap,
            device: DeviceKind::Cpu,
            intra_op_threads: 0,
            trace_sample: 0,
            ..EngineConfig::default()
        },
    )
    .unwrap()
}

fn random_samples(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::new(seed);
    (0..n)
        .map(|_| {
            let mut v = vec![0f32; len];
            rng.fill_uniform(&mut v, 0.0, 1.0);
            v
        })
        .collect()
}

#[test]
fn batched_outputs_match_sequential_single_forwards() {
    let n = 8;
    // One worker + a generous linger: the 8 requests coalesce into one
    // batched forward.
    let engine = lenet_engine(1, n, Duration::from_millis(200), 64);

    let samples = random_samples(n, engine.sample_len(), 42);
    let handles: Vec<_> = samples
        .iter()
        .map(|s| engine.submit(s.clone()).unwrap())
        .collect();
    let got: Vec<Vec<f32>> = handles
        .into_iter()
        .map(|h| h.wait().unwrap().values)
        .collect();
    engine.shutdown();

    let m = engine.metrics().snapshot();
    assert_eq!(m.batches, 1, "expected one coalesced batch, got {}", m.batches);
    assert_eq!(m.batched_samples, n as u64);
    assert_eq!(m.completed, n as u64);

    // Reference: a batch-1 replica adopting the engine's weight snapshot.
    let deploy = zoo::deploy_by_name("lenet", 1).unwrap();
    let mut dev = CpuDevice::new();
    let mut reference = Net::from_param(&deploy.param, Phase::Test, &mut dev).unwrap();
    reference.adopt_weights(&mut dev, &engine.weights()).unwrap();
    let input = reference.blob(&deploy.input).unwrap();
    let output = reference.blob(&deploy.output).unwrap();

    for (i, s) in samples.iter().enumerate() {
        input.borrow_mut().set_data(&mut dev, s);
        reference.forward(&mut dev).unwrap();
        let want = output.borrow_mut().data_vec(&mut dev);
        assert_eq!(got[i].len(), engine.output_len());
        assert_eq!(
            got[i], want,
            "sample {i}: batched output differs from single-sample forward"
        );
    }
}

#[test]
fn shutdown_drains_every_admitted_request() {
    let total = 50;
    let engine = lenet_engine(2, 4, Duration::from_micros(100), 256);
    let samples = random_samples(total, engine.sample_len(), 7);
    let handles: Vec<_> = samples
        .iter()
        .map(|s| engine.submit(s.clone()).unwrap())
        .collect();
    // Shut down immediately: everything already admitted must still be
    // served (close-then-drain), not dropped.
    engine.shutdown();
    for h in handles {
        let resp = h.wait().expect("drained request must get a response");
        assert_eq!(resp.values.len(), engine.output_len());
    }
    let m = engine.metrics().snapshot();
    assert_eq!(m.completed, total as u64);
    assert_eq!(m.failed, 0);
    assert_eq!(m.batched_samples, total as u64);
}

#[test]
fn submit_after_shutdown_is_rejected() {
    let engine = lenet_engine(1, 2, Duration::from_micros(100), 8);
    let len = engine.sample_len();
    engine.shutdown();
    match engine.submit(vec![0.0; len]) {
        Err(ServeError::ShuttingDown) => {}
        other => panic!("expected ShuttingDown, got {other:?}"),
    }
    // Idempotent shutdown.
    engine.shutdown();
}

#[test]
fn wrong_sample_length_is_a_bad_request() {
    let engine = lenet_engine(1, 2, Duration::from_micros(100), 8);
    match engine.submit(vec![0.0; 3]) {
        Err(ServeError::BadRequest(_)) => {}
        other => panic!("expected BadRequest, got {other:?}"),
    }
    engine.shutdown();
}

#[test]
fn multi_worker_pool_serves_valid_probabilities() {
    let total = 40;
    let engine = lenet_engine(4, 8, Duration::from_micros(500), 256);
    let samples = random_samples(total, engine.sample_len(), 13);
    let responses: Vec<_> = samples
        .iter()
        .map(|s| engine.submit(s.clone()).unwrap())
        .map(|h| h.wait().unwrap())
        .collect();
    engine.shutdown();
    for r in &responses {
        assert_eq!(r.values.len(), engine.output_len());
        let sum: f32 = r.values.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "softmax row sum {sum}");
        assert!(r.argmax() < engine.output_len());
    }
    let m = engine.metrics().snapshot();
    assert_eq!(m.completed, total as u64);
    // Same sample set on any worker replica gives the same answer —
    // weights are shared, so resubmitting sample 0 must reproduce
    // responses[0] bit-for-bit. (Engine is shut down; use a replica.)
    let deploy = zoo::deploy_by_name("lenet", 1).unwrap();
    let mut dev = CpuDevice::new();
    let mut replica = Net::from_param(&deploy.param, Phase::Test, &mut dev).unwrap();
    replica.adopt_weights(&mut dev, &engine.weights()).unwrap();
    let input = replica.blob(&deploy.input).unwrap();
    let output = replica.blob(&deploy.output).unwrap();
    input.borrow_mut().set_data(&mut dev, &samples[0]);
    replica.forward(&mut dev).unwrap();
    assert_eq!(
        output.borrow_mut().data_vec(&mut dev),
        responses[0].values
    );
}

/// The core coalescing guarantee must survive intra-op threading: with
/// an explicit multi-thread budget per worker, batched outputs are still
/// bit-identical to sequential batch-1 forwards (the packed GEMM's
/// k-accumulation order is fixed regardless of thread count or batch
/// row count — see math::gemm).
#[test]
fn batched_matches_single_with_intra_op_threads_on() {
    let n = 8;
    let param = zoo::by_name("lenet", 1).unwrap();
    let engine = Engine::new(
        &param,
        EngineConfig {
            workers: 1,
            max_batch: n,
            max_linger: Duration::from_millis(200),
            queue_capacity: 64,
            device: DeviceKind::Cpu,
            // Explicitly multi-threaded kernels inside the worker.
            intra_op_threads: fecaffe::util::pool::default_threads().max(2),
            trace_sample: 0,
            ..EngineConfig::default()
        },
    )
    .unwrap();

    let samples = random_samples(n, engine.sample_len(), 77);
    let handles: Vec<_> = samples
        .iter()
        .map(|s| engine.submit(s.clone()).unwrap())
        .collect();
    let got: Vec<Vec<f32>> = handles
        .into_iter()
        .map(|h| h.wait().unwrap().values)
        .collect();
    engine.shutdown();

    // Reference: serial batch-1 replica on this (unbudgeted) thread.
    let deploy = zoo::deploy_by_name("lenet", 1).unwrap();
    let mut dev = CpuDevice::new();
    let mut reference = Net::from_param(&deploy.param, Phase::Test, &mut dev).unwrap();
    reference.adopt_weights(&mut dev, &engine.weights()).unwrap();
    let input = reference.blob(&deploy.input).unwrap();
    let output = reference.blob(&deploy.output).unwrap();
    for (i, s) in samples.iter().enumerate() {
        input.borrow_mut().set_data(&mut dev, s);
        reference.forward(&mut dev).unwrap();
        let want = output.borrow_mut().data_vec(&mut dev);
        assert_eq!(
            got[i], want,
            "sample {i}: intra-op threading changed batched output bits"
        );
    }
}

/// FPGA-sim workers surface per-batch *simulated* device time in the
/// engine metrics (ROADMAP: evaluate batching policy against the paper's
/// cost model, not host wallclock).
#[test]
fn fpga_sim_workers_report_sim_batch_time() {
    let param = zoo::by_name("lenet", 1).unwrap();
    let engine = Engine::new(
        &param,
        EngineConfig {
            workers: 1,
            max_batch: 4,
            max_linger: Duration::from_micros(100),
            queue_capacity: 64,
            device: DeviceKind::FpgaSim,
            intra_op_threads: 1,
            trace_sample: 0,
            ..EngineConfig::default()
        },
    )
    .unwrap();
    let samples = random_samples(6, engine.sample_len(), 3);
    let handles: Vec<_> = samples
        .iter()
        .map(|s| engine.submit(s.clone()).unwrap())
        .collect();
    for h in handles {
        h.wait().unwrap();
    }
    engine.shutdown();
    let m = engine.metrics().snapshot();
    assert_eq!(m.completed, 6);
    assert!(m.sim_batches >= 1, "sim batches: {}", m.sim_batches);
    assert_eq!(m.sim_batches, m.batches, "every batch metered in sim time");
    assert!(m.sim_total_ns > 0, "forward must advance the sim clock");
    assert!(m.sim_p99_ns >= m.sim_p50_ns);
}
