//! Build probe for the offline-vendored xla crate closure.
//!
//! The real `runtime::pjrt` module needs both the `xla` cargo feature
//! *and* the vendored crate at `../vendor/xla` (it is not on crates.io,
//! so it cannot be an unconditional dependency). This script emits the
//! `xla_vendored` cfg only when the closure is present; without it the
//! `xla` feature still compiles against the dependency-free stub, which
//! is what the CI `xla-check` leg builds.

fn main() {
    // Declare the custom cfg so `unexpected_cfgs` stays quiet on
    // toolchains that check cfg names (older cargos ignore the line).
    println!("cargo:rustc-check-cfg=cfg(xla_vendored)");
    if std::path::Path::new("../vendor/xla/Cargo.toml").is_file() {
        println!("cargo:rustc-cfg=xla_vendored");
    }
    println!("cargo:rerun-if-changed=../vendor/xla/Cargo.toml");
}
