//! Level-1 BLAS + elementwise kernels (paper Table 2: `Add`, `Asum`,
//! `Axpy`, `Scale`, `ReLU_F/B`, `Dropout_F/B`, `Bias`, ...). These are the
//! "BLAS-related" kernel group of the paper's L1 layer.
//!
//! Every *map*-shaped op (disjoint output element per input element)
//! shards across the intra-op pool above [`pool::GRAIN_ELEMWISE`]
//! elements; below that a pool wakeup costs more than the loop.
//! Reductions (`asum`, `dot`) stay serial on purpose: chunked partial
//! sums would make the result depend on the thread count, and these feed
//! loss/gradient-norm numbers that must be identical between the
//! `FECAFFE_THREADS=1` CI leg and the default one.

use crate::util::pool::{self, GRAIN_ELEMWISE};

/// `powf` is ~an order of magnitude more expensive than an FMA, so powx
/// (and the LRN output path) fan out at a smaller grain.
pub(crate) const GRAIN_POWF: usize = 1024;

/// y += alpha * x
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    pool::parallel_chunks_mut(y, GRAIN_ELEMWISE, |off, yc| {
        // Reslice once per chunk: zip gives the compiler bounds-check-free,
        // vectorizable loops (indexing x[off + i] would not).
        let xc = &x[off..off + yc.len()];
        for (yv, &xv) in yc.iter_mut().zip(xc.iter()) {
            *yv += alpha * xv;
        }
    });
}

/// y = alpha * x + beta * y
pub fn axpby(alpha: f32, x: &[f32], beta: f32, y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    pool::parallel_chunks_mut(y, GRAIN_ELEMWISE, |off, yc| {
        let xc = &x[off..off + yc.len()];
        for (yv, &xv) in yc.iter_mut().zip(xc.iter()) {
            *yv = alpha * xv + beta * *yv;
        }
    });
}

/// x *= alpha
pub fn scal(alpha: f32, x: &mut [f32]) {
    pool::parallel_chunks_mut(x, GRAIN_ELEMWISE, |_, xc| {
        for v in xc.iter_mut() {
            *v *= alpha;
        }
    });
}

/// sum of |x| — serial: a fixed summation order keeps the value
/// independent of the thread budget.
pub fn asum(x: &[f32]) -> f32 {
    x.iter().map(|v| v.abs()).sum()
}

/// dot product — serial, same determinism rationale as `asum`.
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(x.len(), y.len());
    x.iter().zip(y.iter()).map(|(a, b)| a * b).sum()
}

/// z = x + y (paper's `Add` kernel — eltwise sum used by Split backward)
pub fn add(x: &[f32], y: &[f32], z: &mut [f32]) {
    assert!(x.len() == y.len() && y.len() == z.len());
    pool::parallel_chunks_mut(z, GRAIN_ELEMWISE, |off, zc| {
        let xc = &x[off..off + zc.len()];
        let yc = &y[off..off + zc.len()];
        for ((zv, &xv), &yv) in zc.iter_mut().zip(xc.iter()).zip(yc.iter()) {
            *zv = xv + yv;
        }
    });
}

/// z = x * y elementwise
pub fn mul(x: &[f32], y: &[f32], z: &mut [f32]) {
    assert!(x.len() == y.len() && y.len() == z.len());
    pool::parallel_chunks_mut(z, GRAIN_ELEMWISE, |off, zc| {
        let xc = &x[off..off + zc.len()];
        let yc = &y[off..off + zc.len()];
        for ((zv, &xv), &yv) in zc.iter_mut().zip(xc.iter()).zip(yc.iter()) {
            *zv = xv * yv;
        }
    });
}

/// y = x^p elementwise
pub fn powx(x: &[f32], p: f32, y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    pool::parallel_chunks_mut(y, GRAIN_POWF, |off, yc| {
        let xc = &x[off..off + yc.len()];
        for (yv, &xv) in yc.iter_mut().zip(xc.iter()) {
            *yv = xv.powf(p);
        }
    });
}

pub fn set(x: &mut [f32], value: f32) {
    pool::parallel_chunks_mut(x, GRAIN_ELEMWISE, |_, xc| {
        for v in xc.iter_mut() {
            *v = value;
        }
    });
}

/// ReLU forward: top = max(bottom, 0) + slope * min(bottom, 0)
pub fn relu_forward(bottom: &[f32], top: &mut [f32], negative_slope: f32) {
    assert_eq!(bottom.len(), top.len());
    pool::parallel_chunks_mut(top, GRAIN_ELEMWISE, |off, tc| {
        let bc = &bottom[off..off + tc.len()];
        for (t, &b) in tc.iter_mut().zip(bc.iter()) {
            *t = if b > 0.0 { b } else { negative_slope * b };
        }
    });
}

/// ReLU backward: bottom_diff = top_diff * (bottom > 0 ? 1 : slope)
pub fn relu_backward(
    bottom_data: &[f32],
    top_diff: &[f32],
    bottom_diff: &mut [f32],
    negative_slope: f32,
) {
    assert!(bottom_data.len() == top_diff.len() && top_diff.len() == bottom_diff.len());
    pool::parallel_chunks_mut(bottom_diff, GRAIN_ELEMWISE, |off, bc| {
        let data = &bottom_data[off..off + bc.len()];
        let td = &top_diff[off..off + bc.len()];
        for ((bd, &dv), &tv) in bc.iter_mut().zip(data.iter()).zip(td.iter()) {
            *bd = tv * if dv > 0.0 { 1.0 } else { negative_slope };
        }
    });
}

/// Dropout forward (train): top = bottom * mask * scale, mask ∈ {0,1}.
/// The mask is produced host-side (Caffe does the same with its RNG) and
/// passed in so forward/backward agree.
pub fn dropout_forward(bottom: &[f32], mask: &[f32], scale: f32, top: &mut [f32]) {
    assert!(bottom.len() == mask.len() && mask.len() == top.len());
    pool::parallel_chunks_mut(top, GRAIN_ELEMWISE, |off, tc| {
        let bc = &bottom[off..off + tc.len()];
        let mc = &mask[off..off + tc.len()];
        for ((t, &bv), &mv) in tc.iter_mut().zip(bc.iter()).zip(mc.iter()) {
            *t = bv * mv * scale;
        }
    });
}

pub fn dropout_backward(top_diff: &[f32], mask: &[f32], scale: f32, bottom_diff: &mut [f32]) {
    assert!(top_diff.len() == mask.len() && mask.len() == bottom_diff.len());
    pool::parallel_chunks_mut(bottom_diff, GRAIN_ELEMWISE, |off, bc| {
        let td = &top_diff[off..off + bc.len()];
        let mc = &mask[off..off + bc.len()];
        for ((bd, &tv), &mv) in bc.iter_mut().zip(td.iter()).zip(mc.iter()) {
            *bd = tv * mv * scale;
        }
    });
}

/// Bias forward (paper's `Bias` kernel): top[n,c,h,w] += bias[c].
/// `dim` = spatial size (H*W), applied over `outer` images of `channels`.
/// Sharded over (image, channel) blocks — each block owns a disjoint
/// `dim`-sized window of `top`.
pub fn bias_forward(top: &mut [f32], bias: &[f32], outer: usize, channels: usize, dim: usize) {
    assert_eq!(top.len(), outer * channels * dim);
    assert_eq!(bias.len(), channels);
    let grain = (GRAIN_ELEMWISE / dim.max(1)).max(1);
    let topp = pool::SendPtr::new(top.as_mut_ptr());
    pool::parallel_for(0..outer * channels, grain, |r| {
        // Safety: (image, channel) block ranges are disjoint across tasks.
        let chunk = unsafe { topp.slice(r.start * dim, r.len() * dim) };
        for (bi, block) in r.zip(chunk.chunks_exact_mut(dim)) {
            let bv = bias[bi % channels];
            for v in block.iter_mut() {
                *v += bv;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_axpby_scal() {
        let x = [1.0, 2.0];
        let mut y = [10.0, 20.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0]);
        axpby(1.0, &x, 0.5, &mut y);
        assert_eq!(y, [7.0, 14.0]);
        scal(2.0, &mut y);
        assert_eq!(y, [14.0, 28.0]);
    }

    #[test]
    fn reductions() {
        assert_eq!(asum(&[1.0, -2.0, 3.0]), 6.0);
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }

    #[test]
    fn eltwise() {
        let mut z = [0.0; 2];
        add(&[1.0, 2.0], &[3.0, 4.0], &mut z);
        assert_eq!(z, [4.0, 6.0]);
        mul(&[2.0, 3.0], &[4.0, 5.0], &mut z);
        assert_eq!(z, [8.0, 15.0]);
        powx(&[4.0, 9.0], 0.5, &mut z);
        assert_eq!(z, [2.0, 3.0]);
    }

    #[test]
    fn eltwise_parallel_matches_serial_above_grain() {
        // Big enough to actually shard on a multi-core budget.
        let n = GRAIN_ELEMWISE * 3 + 17;
        let x: Vec<f32> = (0..n).map(|i| (i % 13) as f32 - 6.0).collect();
        let mut y: Vec<f32> = (0..n).map(|i| (i % 7) as f32).collect();
        let mut y_ref = y.clone();
        axpy(0.5, &x, &mut y);
        for (yv, xv) in y_ref.iter_mut().zip(x.iter()) {
            *yv += 0.5 * xv;
        }
        assert_eq!(y, y_ref);
        let mut z = vec![0.0; n];
        relu_forward(&x, &mut z, 0.1);
        for (i, zv) in z.iter().enumerate() {
            let b = x[i];
            let want = if b > 0.0 { b } else { 0.1 * b };
            assert_eq!(*zv, want);
        }
    }

    #[test]
    fn relu_fwd_bwd() {
        let bottom = [-1.0, 0.0, 2.0];
        let mut top = [0.0; 3];
        relu_forward(&bottom, &mut top, 0.0);
        assert_eq!(top, [0.0, 0.0, 2.0]);
        relu_forward(&bottom, &mut top, 0.1);
        assert_eq!(top, [-0.1, 0.0, 2.0]);

        let top_diff = [1.0, 1.0, 1.0];
        let mut bd = [9.0; 3];
        relu_backward(&bottom, &top_diff, &mut bd, 0.0);
        assert_eq!(bd, [0.0, 0.0, 1.0]);
    }

    #[test]
    fn dropout_scales_kept_units() {
        let bottom = [1.0, 2.0, 3.0, 4.0];
        let mask = [1.0, 0.0, 1.0, 0.0];
        let scale = 2.0; // 1/(1-0.5)
        let mut top = [0.0; 4];
        dropout_forward(&bottom, &mask, scale, &mut top);
        assert_eq!(top, [2.0, 0.0, 6.0, 0.0]);
        let mut bd = [0.0; 4];
        dropout_backward(&top, &mask, scale, &mut bd);
        assert_eq!(bd, [4.0, 0.0, 12.0, 0.0]);
    }

    #[test]
    fn bias_broadcast() {
        // 1 image, 2 channels, dim 2
        let mut top = [0.0, 0.0, 10.0, 10.0];
        bias_forward(&mut top, &[1.0, 2.0], 1, 2, 2);
        assert_eq!(top, [1.0, 1.0, 12.0, 12.0]);
        // 2 images
        let mut top2 = [0.0f32; 8];
        bias_forward(&mut top2, &[1.0, 2.0], 2, 2, 2);
        assert_eq!(top2, [1.0, 1.0, 2.0, 2.0, 1.0, 1.0, 2.0, 2.0]);
    }
}
