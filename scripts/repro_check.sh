#!/usr/bin/env bash
# Reproducible-artifact check: build the full zoo × serving-bucket AOT
# plan matrix twice, in two separate clean directories, from the same
# binary (same commit), and require the two trees to be byte-identical
# — first by diffing the SHA-256 manifests, then (belt and braces) by
# comparing every container file. Any divergence is a determinism
# regression (map iteration order, float formatting, time-dependent
# content) and fails with a readable per-file diff. Finishes with
# `fecaffe aot verify`, which re-derives every content key from the
# live zoo and checks the manifest digests. CI runs this after a
# release build.
set -euo pipefail

FECAFFE="${FECAFFE:-target/release/fecaffe}"
[ -x "$FECAFFE" ] || { echo "fecaffe binary not found at $FECAFFE (set FECAFFE=...)"; exit 1; }

DIR_A="$(mktemp -d)"
DIR_B="$(mktemp -d)"
trap 'rm -rf "$DIR_A" "$DIR_B"' EXIT

echo "== build #1 -> $DIR_A"
"$FECAFFE" aot build --cache-dir "$DIR_A"
echo "== build #2 -> $DIR_B"
"$FECAFFE" aot build --cache-dir "$DIR_B"

# The manifest is the tree: sorted "<sha256>  <relpath>" lines. If the
# manifests agree, the digests pin every file's bytes.
if ! diff -u "$DIR_A/MANIFEST.sha256" "$DIR_B/MANIFEST.sha256"; then
    echo ""
    echo "FAIL: two builds of the same commit produced different manifests."
    echo "Divergent files (byte offsets via cmp):"
    while read -r _hash rel; do
        [ -n "$rel" ] || continue
        if ! cmp -s "$DIR_A/$rel" "$DIR_B/$rel" 2>/dev/null; then
            echo "--- $rel"
            cmp "$DIR_A/$rel" "$DIR_B/$rel" || true
        fi
    done < "$DIR_A/MANIFEST.sha256"
    exit 1
fi

# Manifests identical — confirm the container bytes are too (a manifest
# bug that hashed something else would otherwise slip through).
while read -r _hash rel; do
    [ -n "$rel" ] || continue
    cmp -s "$DIR_A/$rel" "$DIR_B/$rel" || {
        echo "FAIL: $rel differs between builds despite identical manifests:"
        cmp "$DIR_A/$rel" "$DIR_B/$rel" || true
        exit 1
    }
done < "$DIR_A/MANIFEST.sha256"

N="$(wc -l < "$DIR_A/MANIFEST.sha256")"
echo "repro: OK ($N container(s) byte-identical across independent builds)"

echo "== verify against the live zoo"
"$FECAFFE" aot verify --cache-dir "$DIR_A"
echo "repro check: OK"
