//! Stub PJRT backend — compiled when the `xla` cargo feature is off,
//! *or* when it is on without the vendored crate closure (the CI
//! `xla-check` leg; see `build.rs` and the `xla_vendored` cfg).
//!
//! Mirrors the public surface of the real `pjrt` module so every caller
//! (the `fecaffe` CLI, benches, integration tests) builds without the
//! offline-vendored xla crate closure: `auto()` reports that no
//! artifacts are available and `execute` always declines, so kernel
//! launches fall back to the native math library. Build with
//! `--features xla` *and* the vendored `xla` crate under `vendor/xla`
//! for real artifact execution.

use crate::device::fpga::NumericBackend;
use crate::device::native::Slab;
use crate::device::KernelCall;
use std::path::PathBuf;

#[derive(Debug, Default, Clone)]
pub struct BackendStats {
    pub artifact_hits: u64,
    pub artifact_misses: u64,
    pub compiles: u64,
}

/// Placeholder for the PJRT-backed artifact executor.
pub struct PjrtBackend {
    pub stats: BackendStats,
}

impl PjrtBackend {
    /// Always fails: this build has no PJRT client.
    pub fn new(_dir: impl Into<PathBuf>) -> anyhow::Result<PjrtBackend> {
        anyhow::bail!(
            "fecaffe was built without PJRT support; rebuild with \
             `--features xla` and the vendored xla crate (vendor/xla) \
             for artifact execution"
        )
    }

    /// Auto-locate artifacts: always `None` in a stub build.
    pub fn auto() -> Option<PjrtBackend> {
        None
    }
}

impl NumericBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt-stub"
    }

    /// Never claims a kernel: the device runs everything natively.
    fn execute(&mut self, _slab: &mut Slab, _call: &KernelCall) -> anyhow::Result<bool> {
        self.stats.artifact_misses += 1;
        Ok(false)
    }
}
