//! Tokenizer for the protobuf text format subset Caffe uses.

#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    Ident(String),
    Str(String),
    Num(f64),
    LBrace,
    RBrace,
    Colon,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    pub tok: Tok,
    pub line: usize,
}

pub fn lex(text: &str) -> Result<Vec<Token>, String> {
    let mut out = Vec::new();
    let b: Vec<char> = text.chars().collect();
    let mut i = 0;
    let mut line = 1;
    while i < b.len() {
        let c = b[i];
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            ' ' | '\t' | '\r' | ',' => i += 1,
            '#' => {
                while i < b.len() && b[i] != '\n' {
                    i += 1;
                }
            }
            '{' => {
                out.push(Token { tok: Tok::LBrace, line });
                i += 1;
            }
            '}' => {
                out.push(Token { tok: Tok::RBrace, line });
                i += 1;
            }
            ':' => {
                out.push(Token { tok: Tok::Colon, line });
                i += 1;
            }
            '"' | '\'' => {
                let quote = c;
                i += 1;
                let mut s = String::new();
                while i < b.len() && b[i] != quote {
                    if b[i] == '\\' && i + 1 < b.len() {
                        i += 1;
                        s.push(match b[i] {
                            'n' => '\n',
                            't' => '\t',
                            other => other,
                        });
                    } else {
                        if b[i] == '\n' {
                            return Err(format!("line {line}: newline in string"));
                        }
                        s.push(b[i]);
                    }
                    i += 1;
                }
                if i >= b.len() {
                    return Err(format!("line {line}: unterminated string"));
                }
                i += 1; // closing quote
                out.push(Token { tok: Tok::Str(s), line });
            }
            c if c.is_ascii_digit() || c == '-' || c == '+' || c == '.' => {
                let start = i;
                i += 1;
                while i < b.len()
                    && (b[i].is_ascii_digit()
                        || b[i] == '.'
                        || b[i] == 'e'
                        || b[i] == 'E'
                        || b[i] == '-'
                        || b[i] == '+')
                {
                    // Only allow -/+ right after an exponent marker.
                    if (b[i] == '-' || b[i] == '+') && !(b[i - 1] == 'e' || b[i - 1] == 'E') {
                        break;
                    }
                    i += 1;
                }
                let s: String = b[start..i].iter().collect();
                let n = s
                    .parse::<f64>()
                    .map_err(|_| format!("line {line}: bad number '{s}'"))?;
                out.push(Token { tok: Tok::Num(n), line });
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let start = i;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                let s: String = b[start..i].iter().collect();
                out.push(Token { tok: Tok::Ident(s), line });
            }
            other => return Err(format!("line {line}: unexpected character '{other}'")),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexes_typical_prototxt() {
        let toks = lex("layer {\n  name: \"conv1\" # comment\n  lr_mult: 1.5\n}").unwrap();
        let kinds: Vec<&Tok> = toks.iter().map(|t| &t.tok).collect();
        assert_eq!(kinds[0], &Tok::Ident("layer".into()));
        assert_eq!(kinds[1], &Tok::LBrace);
        assert_eq!(kinds[4], &Tok::Str("conv1".into()));
        assert!(matches!(kinds[7], Tok::Num(n) if *n == 1.5));
        assert_eq!(*kinds.last().unwrap(), &Tok::RBrace);
    }

    #[test]
    fn tracks_line_numbers() {
        let toks = lex("a: 1\nb: 2\n\nc: 3").unwrap();
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[3].line, 2);
        assert_eq!(toks[6].line, 4);
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let toks = lex("x: -0.5 y: 1e-3 z: 2.5E+2").unwrap();
        let nums: Vec<f64> = toks
            .iter()
            .filter_map(|t| match t.tok {
                Tok::Num(n) => Some(n),
                _ => None,
            })
            .collect();
        assert_eq!(nums, vec![-0.5, 1e-3, 250.0]);
    }

    #[test]
    fn string_escapes() {
        let toks = lex(r#"s: "a\nb\"c""#).unwrap();
        assert!(matches!(&toks[2].tok, Tok::Str(s) if s == "a\nb\"c"));
    }

    #[test]
    fn errors() {
        assert!(lex("s: \"unterminated").is_err());
        assert!(lex("@").is_err());
    }
}
