//! Deterministic fault injection ("chaos") for the serving stack.
//!
//! A [`FaultPlan`] is a small, seeded recipe of failures to inject into
//! the serve pipeline — transient device-forward faults, worker panics
//! mid-batch, whole-worker deaths and slow batches — parsed from the
//! `FECAFFE_CHAOS` environment variable or `serve --chaos <spec>`. The
//! plan is *deterministic*: every probabilistic decision draws from a
//! [`Pcg32`] stream keyed by the plan seed and a global ticket counter,
//! so a given seed produces the same decision for the i-th draw no
//! matter which worker thread asks. Budgeted events (`panic=N`,
//! `kill=N`, `fault-n=N`) fire exactly N times.
//!
//! Spec grammar — comma-separated `key=value` pairs:
//!
//! ```text
//! seed=7,fault=0.05,fault-n=200,panic=1,panic-after=10,kill=1,kill-after=50,slow=0.01,slow-ms=5
//!
//! seed        PRNG seed for every probabilistic draw        (default 42)
//! fault       P(injected transient device fault per forward attempt)
//! fault-n     budget of injected faults (absent = unlimited)
//! panic       worker panics to inject mid-batch (caught by the worker's
//!             catch_unwind: the batch fails, the replica is rebuilt)
//! panic-after batches to let through before panics arm      (default 0)
//! kill        worker-thread deaths to inject (the thread exits; the
//!             engine supervisor respawns it, with backoff)
//! kill-after  batches to let through before kills arm       (default 0)
//! slow        P(batch delayed by slow-ms before execution)
//! slow-ms     injected delay per slow batch                 (default 1)
//! ```
//!
//! Zero-cost when unset: the engine holds `Option<Arc<ChaosState>>` and
//! every injection point is a `None` check on the hot path.

use crate::util::prng::Pcg32;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Environment variable the engine reads a fault plan from when the
/// config doesn't carry one (`serve --chaos` takes precedence).
pub const CHAOS_ENV: &str = "FECAFFE_CHAOS";

/// Seeded recipe of failures to inject (see module docs for grammar).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Probability that one device-forward *attempt* is replaced by an
    /// injected transient [`crate::device::DeviceError`] (retryable).
    pub fault_p: f32,
    /// Budget of injected transient faults; `u64::MAX` = unlimited.
    pub fault_n: u64,
    /// Worker panics to inject mid-batch.
    pub panic_n: u64,
    /// Batches across the pool before the panic budget arms.
    pub panic_after: u64,
    /// Worker-thread deaths to inject.
    pub kill_n: u64,
    /// Batches across the pool before the kill budget arms.
    pub kill_after: u64,
    /// Probability that a batch sleeps `slow_ms` before executing.
    pub slow_p: f32,
    /// Injected delay per slow batch, milliseconds.
    pub slow_ms: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 42,
            fault_p: 0.0,
            fault_n: u64::MAX,
            panic_n: 0,
            panic_after: 0,
            kill_n: 0,
            kill_after: 0,
            slow_p: 0.0,
            slow_ms: 1,
        }
    }
}

impl FaultPlan {
    /// Parse a spec string (see module docs). Unknown keys and
    /// malformed values are errors — a typo'd chaos plan that silently
    /// injects nothing would defeat the test that set it.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| format!("chaos spec: expected key=value, got '{part}'"))?;
            let (key, value) = (key.trim(), value.trim());
            let int = || -> Result<u64, String> {
                value.parse().map_err(|_| format!("chaos spec: bad integer '{value}' for '{key}'"))
            };
            let prob = || -> Result<f32, String> {
                let p: f32 = value
                    .parse()
                    .map_err(|_| format!("chaos spec: bad probability '{value}' for '{key}'"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("chaos spec: '{key}' must be in [0, 1], got {p}"));
                }
                Ok(p)
            };
            match key {
                "seed" => plan.seed = int()?,
                "fault" => plan.fault_p = prob()?,
                "fault-n" => plan.fault_n = int()?,
                "panic" => plan.panic_n = int()?,
                "panic-after" => plan.panic_after = int()?,
                "kill" => plan.kill_n = int()?,
                "kill-after" => plan.kill_after = int()?,
                "slow" => plan.slow_p = prob()?,
                "slow-ms" => plan.slow_ms = int()?,
                other => return Err(format!("chaos spec: unknown key '{other}'")),
            }
        }
        Ok(plan)
    }

    /// Plan from `FECAFFE_CHAOS`, if set. `Ok(None)` when unset or
    /// empty; a set-but-invalid spec is an error so a typo fails fast
    /// instead of silently running without chaos.
    pub fn from_env() -> Result<Option<FaultPlan>, String> {
        match std::env::var(CHAOS_ENV) {
            Ok(spec) if !spec.trim().is_empty() => FaultPlan::parse(&spec).map(Some),
            _ => Ok(None),
        }
    }

    /// True when the plan injects nothing (every knob at its inert
    /// default) — the engine skips building a [`ChaosState`] for it.
    pub fn is_noop(&self) -> bool {
        self.fault_p == 0.0 && self.panic_n == 0 && self.kill_n == 0 && self.slow_p == 0.0
    }
}

/// Chaos decisions a worker applies at one batch boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct BatchChaos {
    /// Panic inside the worker's guarded batch execution.
    pub panic: bool,
    /// Exit the worker thread (the supervisor's respawn path).
    pub kill: bool,
    /// Sleep this long before executing the batch.
    pub slow: Option<Duration>,
}

/// Shared runtime state for one engine's fault plan: the plan plus the
/// atomic ticket/budget counters that make injection exactly-N and
/// deterministic across the worker pool.
pub struct ChaosState {
    plan: FaultPlan,
    /// One ticket per probabilistic draw — the PRNG stream selector.
    tickets: AtomicU64,
    /// Batches observed across the pool — gates `panic_after`/`kill_after`.
    batches: AtomicU64,
    faults_left: AtomicU64,
    panics_left: AtomicU64,
    kills_left: AtomicU64,
}

/// Decrement a budget if any remains; `u64::MAX` means unlimited and is
/// never decremented. Returns whether the event may fire.
fn take_budget(budget: &AtomicU64) -> bool {
    budget
        .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            if v == u64::MAX {
                Some(v)
            } else {
                v.checked_sub(1)
            }
        })
        .is_ok()
}

impl ChaosState {
    pub fn new(plan: FaultPlan) -> ChaosState {
        ChaosState {
            tickets: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            faults_left: AtomicU64::new(plan.fault_n),
            panics_left: AtomicU64::new(plan.panic_n),
            kills_left: AtomicU64::new(plan.kill_n),
            plan,
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// One seeded coin flip. Each call consumes a ticket; the outcome
    /// for ticket i is a pure function of (seed, i).
    fn flip(&self, p: f32) -> bool {
        if p <= 0.0 {
            return false;
        }
        let ticket = self.tickets.fetch_add(1, Ordering::Relaxed);
        Pcg32::with_stream(self.plan.seed, ticket).bernoulli(p)
    }

    /// Decisions for the batch a worker just popped. Called once per
    /// batch (before execution); panic takes priority over kill when
    /// both budgets fire on the same batch.
    pub fn on_batch(&self) -> BatchChaos {
        let seen = self.batches.fetch_add(1, Ordering::Relaxed);
        let panic = seen >= self.plan.panic_after
            && self.panics_left.load(Ordering::Relaxed) > 0
            && take_budget(&self.panics_left);
        let kill = !panic
            && seen >= self.plan.kill_after
            && self.kills_left.load(Ordering::Relaxed) > 0
            && take_budget(&self.kills_left);
        let slow = (self.flip(self.plan.slow_p))
            .then(|| Duration::from_millis(self.plan.slow_ms));
        BatchChaos { panic, kill, slow }
    }

    /// Should this device-forward attempt be replaced by an injected
    /// transient fault? Drawn per *attempt*, so a retried forward draws
    /// again — which is what lets a bounded retry recover from it.
    pub fn draw_fault(&self) -> Option<String> {
        if self.faults_left.load(Ordering::Relaxed) == 0 || !self.flip(self.plan.fault_p) {
            return None;
        }
        if !take_budget(&self.faults_left) {
            return None;
        }
        Some("chaos: injected transient device fault".to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec_and_defaults() {
        let p = FaultPlan::parse(
            "seed=7, fault=0.05, fault-n=200, panic=1, panic-after=10, \
             kill=2, kill-after=50, slow=0.5, slow-ms=3",
        )
        .unwrap();
        assert_eq!(p.seed, 7);
        assert!((p.fault_p - 0.05).abs() < 1e-9);
        assert_eq!(p.fault_n, 200);
        assert_eq!((p.panic_n, p.panic_after), (1, 10));
        assert_eq!((p.kill_n, p.kill_after), (2, 50));
        assert!((p.slow_p - 0.5).abs() < 1e-9);
        assert_eq!(p.slow_ms, 3);
        // Defaults: empty spec is the inert plan.
        let d = FaultPlan::parse("").unwrap();
        assert_eq!(d, FaultPlan::default());
        assert!(d.is_noop());
        assert!(!p.is_noop());
    }

    #[test]
    fn parse_rejects_typos_loudly() {
        assert!(FaultPlan::parse("falt=0.5").is_err());
        assert!(FaultPlan::parse("fault").is_err());
        assert!(FaultPlan::parse("fault=nope").is_err());
        assert!(FaultPlan::parse("fault=1.5").is_err());
        assert!(FaultPlan::parse("panic=-1").is_err());
    }

    #[test]
    fn budgets_fire_exactly_n_times() {
        let s = ChaosState::new(FaultPlan::parse("panic=2,panic-after=3,kill=1,kill-after=0").unwrap());
        let mut panics = 0;
        let mut kills = 0;
        for _ in 0..100 {
            let c = s.on_batch();
            panics += u32::from(c.panic);
            kills += u32::from(c.kill);
        }
        assert_eq!(panics, 2);
        assert_eq!(kills, 1);
        // The panic budget armed only after 3 batches: the first firing
        // batch index is >= 3 by construction (checked via arming gate).
        let s2 = ChaosState::new(FaultPlan::parse("panic=1,panic-after=3").unwrap());
        let fired: Vec<bool> = (0..10).map(|_| s2.on_batch().panic).collect();
        assert!(!fired[0] && !fired[1] && !fired[2]);
        assert!(fired[3]);
    }

    #[test]
    fn fault_draws_are_seeded_and_budgeted() {
        // Same seed → same decision sequence.
        let a = ChaosState::new(FaultPlan::parse("seed=9,fault=0.3").unwrap());
        let b = ChaosState::new(FaultPlan::parse("seed=9,fault=0.3").unwrap());
        let da: Vec<bool> = (0..64).map(|_| a.draw_fault().is_some()).collect();
        let db: Vec<bool> = (0..64).map(|_| b.draw_fault().is_some()).collect();
        assert_eq!(da, db);
        let hits = da.iter().filter(|&&h| h).count();
        assert!(hits > 0 && hits < 64, "p=0.3 over 64 draws hit {hits} times");
        // A budget caps the total no matter how many draws are made.
        let c = ChaosState::new(FaultPlan::parse("seed=9,fault=1.0,fault-n=5").unwrap());
        let hits = (0..100).filter(|_| c.draw_fault().is_some()).count();
        assert_eq!(hits, 5);
    }

    #[test]
    fn slow_batches_carry_the_configured_delay() {
        let s = ChaosState::new(FaultPlan::parse("slow=1.0,slow-ms=7").unwrap());
        assert_eq!(s.on_batch().slow, Some(Duration::from_millis(7)));
        let inert = ChaosState::new(FaultPlan::default());
        let c = inert.on_batch();
        assert_eq!(c, BatchChaos { panic: false, kill: false, slow: None });
    }
}
